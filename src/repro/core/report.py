"""TALP reporting: post-mortem text (paper-style) + JSON, node-scan tables.

TALP's post-mortem output is "available both as plain text in a
human-readable format and as a JSON file, enabling automated processing".
We reproduce both, plus the paper's Tables 1–3 layout (metric hierarchy
vs node count) and — beyond the paper — a multi-run scalability join.

Every layout is *derived* from the declarative specs in
:mod:`repro.core.hierarchy`: the text tree drawing, the JSON key order
and the table rows all walk the hierarchy, so a metric registered with
``Hierarchy.with_child`` appears in every output format automatically.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .analysis import TraceAnalysis
from .hierarchy import DEVICE, HOST, Hierarchy, MetricFrame
from .talp import RegionResult, TalpResult

__all__ = [
    "render_text",
    "render_tables",
    "render_metrics",
    "to_json",
    "from_json",
    "node_scan_table",
]

Result = Union[RegionResult, TraceAnalysis]


def _pct(x: Optional[float]) -> str:
    return "   n/a" if x is None else f"{100.0 * x:5.1f}%"


def _as_frame(obj, hierarchy: Hierarchy) -> MetricFrame:
    """Metrics façade (or already-computed frame) → MetricFrame."""
    if isinstance(obj, MetricFrame):
        return obj
    return hierarchy.frame_of(obj)


def _labelled_rows(frame: MetricFrame) -> List[Tuple[str, float]]:
    """(tree-drawn label, value) rows in report order: the multiplicative
    tree first (``|-``/`` `-`` prefixes), then annotation/extension nodes
    flagged ``[ext]``."""
    h = frame.hierarchy
    rows: List[Tuple[str, float]] = []
    ext: List[Tuple[str, float]] = []

    def rec(spec, prefix: str) -> None:
        mult = [
            c for c in spec.children
            if c.multiplicative and c.key in frame.values
        ]
        for c in spec.children:
            if c.key not in frame.values:
                continue
            if not c.multiplicative:
                ext.append((f"[ext] {c.display}", frame.values[c.key]))
                continue
            last = c is mult[-1]
            rows.append(
                (f"{prefix}{'`- ' if last else '|- '}{c.display}",
                 frame.values[c.key])
            )
            rec(c, prefix + ("    " if last else "|   "))

    rows.append((h.root.display, frame.values[h.root.key]))
    rec(h.root, "")
    return rows + ext


def render_metrics(frame_or_metrics, hierarchy: Optional[Hierarchy] = None) -> str:
    """Render one hierarchy's metric block (the per-side lines of
    :func:`render_text`) from a frame or a metrics façade."""
    if isinstance(frame_or_metrics, MetricFrame):
        frame = frame_or_metrics
    else:
        if hierarchy is None:
            raise ValueError("need a hierarchy to render a plain metrics object")
        frame = hierarchy.frame_of(frame_or_metrics)
    return "\n".join(_metric_lines(frame))


def _metric_lines(frame: MetricFrame) -> List[str]:
    side = frame.hierarchy.side
    return [
        f"{side if i == 0 else '':8s}{label:27s}{_pct(value)}"
        for i, (label, value) in enumerate(_labelled_rows(frame))
    ]


def render_text(result: Result, title: Optional[str] = None) -> str:
    """Paper-figure-style text report for one region/trace."""
    name = getattr(result, "name", "Global")
    n_ranks = getattr(result, "n_ranks", None) or len(result.host_states) or 0
    n_devs = getattr(result, "n_devices", None) or len(result.device_states) or 0
    head = title or f'TALP report - region "{name}"'
    lines = [
        "=" * 64,
        f"{head}",
        f"elapsed {result.elapsed:.6f} s | {n_ranks} rank(s) | {n_devs} device(s)",
        "=" * 64,
    ]
    if result.host is not None:
        lines += _metric_lines(_as_frame(result.host, HOST))
    if result.device is not None:
        lines += _metric_lines(_as_frame(result.device, DEVICE))
    if result.host_states:
        lines.append("-" * 64)
        lines.append("host states (s):   rank    useful    offload        mpi")
        for r, st in sorted(result.host_states.items()):
            lines.append(
                f"                  {r:5d} {st['useful']:9.4f}  {st['offload']:9.4f}  {st['mpi']:9.4f}"
            )
    if result.device_states:
        lines.append("device states (s): dev     kernel     memory       idle")
        for d, st in sorted(result.device_states.items()):
            lines.append(
                f"                  {d:5d} {st['kernel']:9.4f}  {st['memory']:9.4f}  {st['idle']:9.4f}"
            )
    lines.append("=" * 64)
    return "\n".join(lines)


def render_tables(result: TalpResult) -> str:
    """Render every region of a TalpResult; a partial job report
    (``rank_coverage`` set by a tolerant merge) gets a trailing coverage
    block naming the missing/quarantined ranks."""
    parts = [render_text(r, title=f'{result.name} - region "{name}"')
             for name, r in sorted(result.regions.items())]
    cov = getattr(result, "rank_coverage", None)
    if cov is not None:
        parts.append(cov.render_text())
    return "\n\n".join(parts)


def _result_dict(result: Result) -> Dict:
    return {
        "name": getattr(result, "name", "Global"),
        "elapsed": result.elapsed,
        "host_metrics": result.host.as_dict() if result.host else None,
        "device_metrics": result.device.as_dict() if result.device else None,
        "host_states": {str(k): v for k, v in result.host_states.items()},
        "device_states": {str(k): v for k, v in result.device_states.items()},
    }


def to_json(result: Union[Result, TalpResult], indent: int = 2) -> str:
    """Machine-readable output (TALP's JSON path). A tolerant merge's
    ``rank_coverage`` annotation round-trips as a top-level node."""
    if isinstance(result, TalpResult):
        payload = {
            "talp": result.name,
            "regions": {n: _result_dict(r) for n, r in result.regions.items()},
        }
        cov = result.rank_coverage
        if cov is not None:
            payload["rank_coverage"] = (
                cov.as_dict() if hasattr(cov, "as_dict") else cov
            )
    else:
        payload = _result_dict(result)
    return json.dumps(payload, indent=indent)


def from_json(text: str) -> Dict:
    return json.loads(text)


def _table_rows(hierarchy: Hierarchy) -> List[Tuple[str, str]]:
    """Paper Tables 1–3 row labels, derived from the spec: depth-0 bare,
    depth-1 ``- `` bullet, deeper indented; annotation nodes excluded."""
    rows: List[Tuple[str, str]] = []

    def rec(spec, depth: int) -> None:
        if not spec.multiplicative:
            return
        indent = "" if depth == 0 else ("- " if depth == 1 else "    ")
        rows.append((indent + spec.display, spec.key))
        for c in spec.children:
            rec(c, depth + 1)

    rec(hierarchy.root, 0)
    return rows


def node_scan_table(
    results: Sequence[Result],
    labels: Sequence[str],
    title: str = "TALP Output",
    host_hierarchy: Hierarchy = HOST,
    device_hierarchy: Hierarchy = DEVICE,
) -> str:
    """Paper Tables 1–3 layout: metric hierarchy rows × run columns."""
    if len(results) != len(labels):
        raise ValueError("results/labels length mismatch")
    width = max(7, max(len(str(l)) for l in labels) + 2)
    header = f"{title}\n{'':8s}{'Metrics':28s}" + "".join(
        f"{str(l):>{width}s}" for l in labels
    )
    lines = [header]

    def row(side: str, label: str, values: List[Optional[float]]):
        cells = "".join(
            f"{'':>{width - 4}s} n/a" if v is None else f"{v:>{width}.2f}"
            for v in values
        )
        lines.append(f"{side:8s}{label:28s}{cells}")

    def value_of(obj, key: str) -> Optional[float]:
        if obj is None:
            return None
        if isinstance(obj, MetricFrame):
            return obj.get(key)
        return getattr(obj, key, None)

    for i, (label, key) in enumerate(_table_rows(host_hierarchy)):
        row(host_hierarchy.side if i == 0 else "", label,
            [value_of(r.host, key) for r in results])
    for i, (label, key) in enumerate(_table_rows(device_hierarchy)):
        row(device_hierarchy.side if i == 0 else "", label,
            [value_of(r.device, key) for r in results])
    return "\n".join(lines)
