"""TALP reporting: post-mortem text (paper-style) + JSON, node-scan tables.

TALP's post-mortem output is "available both as plain text in a
human-readable format and as a JSON file, enabling automated processing".
We reproduce both, plus the paper's Tables 1–3 layout (metric hierarchy
vs node count) and — beyond the paper — a multi-run scalability join.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional, Sequence, Union

from .analysis import TraceAnalysis
from .device_metrics import DeviceMetrics
from .host_metrics import HostMetrics
from .talp import RegionResult, TalpResult

__all__ = [
    "render_text",
    "render_tables",
    "to_json",
    "from_json",
    "node_scan_table",
]

Result = Union[RegionResult, TraceAnalysis]


def _pct(x: Optional[float]) -> str:
    return "   n/a" if x is None else f"{100.0 * x:5.1f}%"


def _host_lines(hm: HostMetrics) -> List[str]:
    return [
        f"Host    Parallel Efficiency        {_pct(hm.parallel_efficiency)}",
        f"        |- MPI Parallel Eff.       {_pct(hm.mpi_parallel_efficiency)}",
        f"        |   |- Comm. Eff.          {_pct(hm.communication_efficiency)}",
        f"        |   `- Load Balance        {_pct(hm.load_balance)}",
        f"        `- Device Offload Eff.     {_pct(hm.device_offload_efficiency)}",
    ]


def _device_lines(dm: DeviceMetrics) -> List[str]:
    lines = [
        f"Device  Parallel Efficiency        {_pct(dm.parallel_efficiency)}",
        f"        |- Load Balance            {_pct(dm.load_balance)}",
        f"        |- Communication Eff.      {_pct(dm.communication_efficiency)}",
        f"        `- Orchestration Eff.      {_pct(dm.orchestration_efficiency)}",
    ]
    if dm.computational_efficiency is not None:
        lines.append(
            f"        [ext] Computational Eff.   {_pct(dm.computational_efficiency)}"
        )
    return lines


def render_text(result: Result, title: Optional[str] = None) -> str:
    """Paper-figure-style text report for one region/trace."""
    name = getattr(result, "name", "Global")
    n_ranks = getattr(result, "n_ranks", None) or len(result.host_states) or 0
    n_devs = getattr(result, "n_devices", None) or len(result.device_states) or 0
    head = title or f'TALP report - region "{name}"'
    lines = [
        "=" * 64,
        f"{head}",
        f"elapsed {result.elapsed:.6f} s | {n_ranks} rank(s) | {n_devs} device(s)",
        "=" * 64,
    ]
    if result.host is not None:
        lines += _host_lines(result.host)
    if result.device is not None:
        lines += _device_lines(result.device)
    if result.host_states:
        lines.append("-" * 64)
        lines.append("host states (s):   rank    useful    offload        mpi")
        for r, st in sorted(result.host_states.items()):
            lines.append(
                f"                  {r:5d} {st['useful']:9.4f}  {st['offload']:9.4f}  {st['mpi']:9.4f}"
            )
    if result.device_states:
        lines.append("device states (s): dev     kernel     memory       idle")
        for d, st in sorted(result.device_states.items()):
            lines.append(
                f"                  {d:5d} {st['kernel']:9.4f}  {st['memory']:9.4f}  {st['idle']:9.4f}"
            )
    lines.append("=" * 64)
    return "\n".join(lines)


def render_tables(result: TalpResult) -> str:
    """Render every region of a TalpResult."""
    parts = [render_text(r, title=f'{result.name} - region "{name}"')
             for name, r in sorted(result.regions.items())]
    return "\n\n".join(parts)


def _result_dict(result: Result) -> Dict:
    return {
        "name": getattr(result, "name", "Global"),
        "elapsed": result.elapsed,
        "host_metrics": result.host.as_dict() if result.host else None,
        "device_metrics": result.device.as_dict() if result.device else None,
        "host_states": {str(k): v for k, v in result.host_states.items()},
        "device_states": {str(k): v for k, v in result.device_states.items()},
    }


def to_json(result: Union[Result, TalpResult], indent: int = 2) -> str:
    """Machine-readable output (TALP's JSON path)."""
    if isinstance(result, TalpResult):
        payload = {
            "talp": result.name,
            "regions": {n: _result_dict(r) for n, r in result.regions.items()},
        }
    else:
        payload = _result_dict(result)
    return json.dumps(payload, indent=indent)


def from_json(text: str) -> Dict:
    return json.loads(text)


_HOST_ROWS = [
    ("Parallel Efficiency", "parallel_efficiency"),
    ("- MPI Parallel Eff.", "mpi_parallel_efficiency"),
    ("    Comm. Eff.", "communication_efficiency"),
    ("    Load Balance", "load_balance"),
    ("- Device Offload Eff.", "device_offload_efficiency"),
]
_DEV_ROWS = [
    ("Parallel Efficiency", "parallel_efficiency"),
    ("- Load Balance", "load_balance"),
    ("- Communication Eff.", "communication_efficiency"),
    ("- Orchestration Eff.", "orchestration_efficiency"),
]


def node_scan_table(
    results: Sequence[Result],
    labels: Sequence[str],
    title: str = "TALP Output",
) -> str:
    """Paper Tables 1–3 layout: metric hierarchy rows × run columns."""
    if len(results) != len(labels):
        raise ValueError("results/labels length mismatch")
    width = max(7, max(len(str(l)) for l in labels) + 2)
    header = f"{title}\n{'':8s}{'Metrics':28s}" + "".join(
        f"{str(l):>{width}s}" for l in labels
    )
    lines = [header]

    def row(side: str, label: str, values: List[Optional[float]]):
        cells = "".join(
            f"{'':>{width - 4}s} n/a" if v is None else f"{v:>{width}.2f}"
            for v in values
        )
        lines.append(f"{side:8s}{label:28s}{cells}")

    for i, (label, attr) in enumerate(_HOST_ROWS):
        vals = [getattr(r.host, attr) if r.host else None for r in results]
        row("Host" if i == 0 else "", label, vals)
    for i, (label, attr) in enumerate(_DEV_ROWS):
        vals = [getattr(r.device, attr) if r.device else None for r in results]
        row("Device" if i == 0 else "", label, vals)
    return "\n".join(lines)
