"""Interval algebra for TALP activity-record post-processing.

Implements the paper's uniform, backend-independent post-processing step
(§4.2):

  * kernel records from all streams are *flattened* into disjoint
    execution intervals,
  * memory-transfer records are flattened and any overlap with kernel
    intervals is *subtracted* (device-level overlap counts as
    computation),
  * the uncovered remainder of the window is classified as *idle*.

Intervals are represented as float64 ndarrays of shape (N, 2) with
columns (start, end), ``end >= start``. All functions return flattened
(sorted, disjoint) intervals and are pure.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "EMPTY",
    "as_intervals",
    "flatten",
    "total",
    "subtract",
    "intersect",
    "union",
    "gaps",
    "clip",
    "is_flat",
    "window_total",
]

EMPTY = np.zeros((0, 2), dtype=np.float64)


def as_intervals(pairs) -> np.ndarray:
    """Coerce a sequence of (start, end) pairs to the canonical ndarray form."""
    arr = np.asarray(pairs, dtype=np.float64)
    if arr.size == 0:
        return EMPTY.copy()
    arr = arr.reshape(-1, 2)
    if np.any(arr[:, 1] < arr[:, 0]):
        raise ValueError("interval with end < start")
    return arr


def is_flat(iv: np.ndarray) -> bool:
    """True if intervals are sorted, disjoint and non-degenerate-ordered."""
    iv = as_intervals(iv)
    if len(iv) <= 1:
        return True
    return bool(np.all(iv[1:, 0] >= iv[:-1, 1]))


def flatten(iv: np.ndarray) -> np.ndarray:
    """Merge overlapping/touching intervals into a sorted disjoint set.

    This is the paper's "kernel execution records are flattened so that
    overlapping launches across streams are merged into a single
    continuous execution interval".
    """
    iv = as_intervals(iv)
    # Drop zero-length intervals; they carry no duration.
    iv = iv[iv[:, 1] > iv[:, 0]]
    if len(iv) == 0:
        return EMPTY.copy()
    # Fast path: already sorted and disjoint (the common case on the hot
    # post-processing path, where most inputs were flattened upstream).
    if len(iv) == 1 or bool(np.all(iv[1:, 0] > iv[:-1, 1])):
        return iv.copy()
    # Sort by start only: the merged output is independent of the order
    # of equal starts (an interval never opens a group inside its own
    # equal-start block, since every earlier end > the shared start), so
    # the cheaper single-key unstable sort is exact.
    order = np.argsort(iv[:, 0], kind="quicksort")
    iv = iv[order]
    # Vectorized merge: a new group starts where start > running max of
    # previous ends.
    run_max_end = np.maximum.accumulate(iv[:, 1])
    new_group = np.ones(len(iv), dtype=bool)
    new_group[1:] = iv[1:, 0] > run_max_end[:-1]
    group_id = np.cumsum(new_group) - 1
    n_groups = group_id[-1] + 1
    starts = np.zeros(n_groups)
    ends = np.zeros(n_groups)
    # first element of each group has the min start (sorted by start)
    first_idx = np.flatnonzero(new_group)
    starts = iv[first_idx, 0]
    ends = np.maximum.reduceat(iv[:, 1], first_idx)
    return np.stack([starts, ends], axis=1)


def total(iv: np.ndarray) -> float:
    """Total covered duration. Flattens first so overlap is not double counted."""
    iv = flatten(iv)
    if len(iv) == 0:
        return 0.0
    return float(np.sum(iv[:, 1] - iv[:, 0]))


def _intersect_flat(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Vectorized core: intersection of two flattened, non-empty sets.

    For each interval of ``a`` the overlapping run of ``b`` intervals is
    located with two binary searches; the (a_i, b_j) overlap pairs are then
    materialized with a repeat/cumsum expansion. For flattened inputs the
    total number of pairs is at most ``len(a) + len(b) - 1``, so the
    expansion is linear in the input size.
    """
    # first j with b_end > a_start  /  first j with b_start >= a_end
    lo = np.searchsorted(b[:, 1], a[:, 0], side="right")
    hi = np.searchsorted(b[:, 0], a[:, 1], side="left")
    cnt = hi - lo
    total = int(cnt.sum())
    if total == 0:
        return EMPTY.copy()
    ai = np.repeat(np.arange(len(a)), cnt)
    offsets = np.concatenate(([0], np.cumsum(cnt)[:-1]))
    bj = lo[ai] + np.arange(total) - np.repeat(offsets, cnt)
    s = np.maximum(a[ai, 0], b[bj, 0])
    e = np.minimum(a[ai, 1], b[bj, 1])
    keep = e > s
    if not keep.all():
        s, e = s[keep], e[keep]
    if len(s) == 0:
        return EMPTY.copy()
    return np.stack([s, e], axis=1)


def subtract(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Parts of ``a`` not covered by ``b`` (both flattened first).

    Used for "memory transfer records ... segments overlapping with
    kernel intervals are removed to avoid double counting".

    Computed as ``a ∩ complement(b)`` over a hull containing ``a``, with
    the vectorized intersection core (no Python-level loops).
    """
    a = flatten(a)
    if len(a) == 0:
        return a
    b = flatten(b)
    if len(b) == 0:
        return a
    # Complement of b within a hull strictly containing a: the gaps
    # between consecutive b intervals plus two sentinel flanks.
    hull_lo = min(a[0, 0], b[0, 0]) - 1.0
    hull_hi = max(a[-1, 1], b[-1, 1]) + 1.0
    comp = np.empty((len(b) + 1, 2), dtype=np.float64)
    comp[0, 0] = hull_lo
    comp[1:, 0] = b[:, 1]
    comp[:-1, 1] = b[:, 0]
    comp[-1, 1] = hull_hi
    comp = comp[comp[:, 1] > comp[:, 0]]
    if len(comp) == 0:
        return EMPTY.copy()
    return _intersect_flat(a, comp)


def intersect(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Intervals covered by both ``a`` and ``b``."""
    a = flatten(a)
    b = flatten(b)
    if len(a) == 0 or len(b) == 0:
        return EMPTY.copy()
    return _intersect_flat(a, b)


def _subtract_loop(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Reference scalar implementation of :func:`subtract` (kept for the
    equivalence property tests and the vectorization benchmark)."""
    a = flatten(a)
    b = flatten(b)
    if len(a) == 0 or len(b) == 0:
        return a
    out = []
    j = 0
    for s, e in a:
        cur = s
        while j < len(b) and b[j, 1] <= cur:
            j += 1
        k = j
        while k < len(b) and b[k, 0] < e:
            bs, be = b[k]
            if bs > cur:
                out.append((cur, bs))
            cur = max(cur, be)
            if cur >= e:
                break
            k += 1
        if cur < e:
            out.append((cur, e))
    return as_intervals(out) if out else EMPTY.copy()


def _intersect_loop(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Reference scalar implementation of :func:`intersect` (kept for the
    equivalence property tests and the vectorization benchmark)."""
    a = flatten(a)
    b = flatten(b)
    if len(a) == 0 or len(b) == 0:
        return EMPTY.copy()
    out = []
    i = j = 0
    while i < len(a) and j < len(b):
        s = max(a[i, 0], b[j, 0])
        e = min(a[i, 1], b[j, 1])
        if s < e:
            out.append((s, e))
        if a[i, 1] <= b[j, 1]:
            i += 1
        else:
            j += 1
    return as_intervals(out) if out else EMPTY.copy()


def union(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Flattened union of two interval sets."""
    a = as_intervals(a)
    b = as_intervals(b)
    if len(a) == 0:
        return flatten(b)
    if len(b) == 0:
        return flatten(a)
    return flatten(np.concatenate([a, b], axis=0))


def gaps(iv: np.ndarray, start: float, end: float) -> np.ndarray:
    """Uncovered sub-intervals of [start, end] — the paper's *inactive time*."""
    if end < start:
        raise ValueError("window end < start")
    window = as_intervals([(start, end)])
    return subtract(window, iv)


def clip(iv: np.ndarray, start: float, end: float) -> np.ndarray:
    """Restrict intervals to the window [start, end]."""
    return intersect(iv, as_intervals([(start, end)]))


def window_total(flat: np.ndarray, start: float, end: float) -> float:
    """Total overlap of an *already flattened* interval set with the
    window [start, end].

    The per-step capture path calls this once per region close against
    the full flattened history, so it must not touch intervals outside
    the window: two binary searches locate the overlapping run and only
    that slice is clipped — O(log n + k) instead of the O(n) revalidation
    a generic ``total(intersect(...))`` would pay."""
    if len(flat) == 0 or end <= start:
        return 0.0
    lo = int(np.searchsorted(flat[:, 1], start, side="right"))
    hi = int(np.searchsorted(flat[:, 0], end, side="left"))
    if hi <= lo:
        return 0.0
    s = np.maximum(flat[lo:hi, 0], start)
    e = np.minimum(flat[lo:hi, 1], end)
    return float(np.sum(e - s))
