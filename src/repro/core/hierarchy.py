"""Declarative metric-hierarchy engine: one spec drives everything.

The paper's core contribution is a *multiplicative hierarchy* of host and
device efficiency metrics (eqs. 1–12, Figs. 1–3, Tables 1–3): each parent
metric is the product of its children. This module encodes that hierarchy
exactly once, as data:

  * :class:`StateDurations` — the common input record: per-rank host state
    durations (Useful, Offload, MPI), per-device state durations (Kernel,
    Memory), the elapsed time E (paper eq. 1) and free-form ``extras``.
  * :class:`MetricSpec` — one metric node: a stable ``key``, a report
    ``display`` name, a ``formula`` over :class:`StateDurations`, and its
    children. ``multiplicative=False`` marks annotation/extension nodes
    that are reported but excluded from the parent≡Π(children) invariant;
    ``optional=True`` marks nodes whose formula may return ``None`` (the
    node is then simply absent from the computed frame).
  * :class:`Hierarchy` — a named tree of specs with ``compute()`` →
    :class:`MetricFrame`, generic validation, and ``with_child()`` for
    registering new metrics without touching any other layer.
  * :class:`MetricFrame` — the computed values, in hierarchy order, with
    generic ``validate()`` (parent = product of multiplicative children),
    ``as_dict()`` (report JSON layout) and ``tree()`` (MetricNode view).

Every other layer derives from these specs: ``pop.py`` /
``host_metrics.py`` / ``device_metrics.py`` are thin dataclass façades
over :data:`POP` / :data:`HOST` / :data:`DEVICE`, ``tree.py`` builds its
``MetricNode`` trees from frames, ``report.py`` renders text / JSON /
node-scan tables generically from the specs (a registered metric appears
in every output format automatically), and ``merge.py`` /
``scalability.py`` recompute job-level metrics through the engine from
merged :class:`StateDurations`. Each paper formula is therefore stated
exactly once, in the instances at the bottom of this file.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Iterator, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "StateDurations",
    "MetricSpec",
    "MetricFrame",
    "Hierarchy",
    "elapsed_time",
    "POP",
    "HOST",
    "DEVICE",
    "SCALABILITY",
]


def elapsed_time(useful: Sequence[float], not_useful: Sequence[float]) -> float:
    """Eq. (1): E = max_i (D_U_i + D_notU_i)."""
    u = np.asarray(useful, dtype=np.float64)
    nu = np.asarray(not_useful, dtype=np.float64)
    if u.shape != nu.shape or u.ndim != 1 or len(u) == 0:
        raise ValueError("useful/not_useful must be equal-length 1-D, non-empty")
    return float(np.max(u + nu))


# ---------------------------------------------------------------------------
# the common input record
# ---------------------------------------------------------------------------
@dataclass
class StateDurations:
    """Per-rank / per-device state durations — the one record every
    hierarchy formula is written against.

    Host arrays are indexed by rank position, device arrays by device
    position; ``offload``/``mpi`` (resp. ``memory``) default to zeros of
    the matching shape. ``extras`` carries scalar side-channel inputs
    (e.g. an externally measured ``computational_efficiency``, or the
    baseline quantities of a scalability scan).
    """

    elapsed: float = 0.0
    useful: Optional[np.ndarray] = None
    offload: Optional[np.ndarray] = None
    mpi: Optional[np.ndarray] = None
    kernel: Optional[np.ndarray] = None
    memory: Optional[np.ndarray] = None
    extras: Dict[str, float] = field(default_factory=dict)
    _host_work: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False
    )
    _device_work: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        def arr(x, like):
            if x is None:
                return np.zeros(0 if like is None else len(like), dtype=np.float64)
            return np.asarray(x, dtype=np.float64)

        self.useful = arr(self.useful, None)
        self.offload = arr(self.offload, self.useful)
        self.mpi = arr(self.mpi, self.useful)
        self.kernel = arr(self.kernel, None)
        self.memory = arr(self.memory, self.kernel)

    # -- derived vectors (cached; shared by several formulas) ---------------
    @property
    def host_work(self) -> np.ndarray:
        """Useful + Offload: the "offload counts as useful" MPI-level view."""
        if self._host_work is None:
            self._host_work = self.useful + self.offload
        return self._host_work

    @property
    def device_work(self) -> np.ndarray:
        """Kernel + Memory: the non-idle device occupancy."""
        if self._device_work is None:
            self._device_work = self.kernel + self.memory
        return self._device_work

    @property
    def n_ranks(self) -> int:
        return len(self.useful)

    @property
    def n_devices(self) -> int:
        return len(self.kernel)

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_host(
        cls,
        useful: Sequence[float],
        offload: Sequence[float],
        mpi: Optional[Sequence[float]] = None,
        elapsed: Optional[float] = None,
    ) -> "StateDurations":
        u = np.asarray(useful, dtype=np.float64)
        w = np.asarray(offload, dtype=np.float64)
        m = None if mpi is None else np.asarray(mpi, dtype=np.float64)
        if elapsed is None:
            if m is None:
                raise ValueError("need mpi durations or explicit elapsed")
            elapsed = elapsed_time(u, w + m)
        return cls(elapsed=float(elapsed), useful=u, offload=w, mpi=m)

    @classmethod
    def from_device(
        cls,
        kernel: Sequence[float],
        memory: Sequence[float],
        elapsed: float,
        extras: Optional[Dict[str, float]] = None,
    ) -> "StateDurations":
        return cls(
            elapsed=float(elapsed),
            kernel=np.asarray(kernel, dtype=np.float64),
            memory=np.asarray(memory, dtype=np.float64),
            extras=dict(extras or {}),
        )

    @classmethod
    def from_states(
        cls,
        host_states: Optional[Dict[int, Dict[str, float]]] = None,
        device_states: Optional[Dict[int, Dict[str, float]]] = None,
        elapsed: float = 0.0,
        extras: Optional[Dict[str, float]] = None,
    ) -> "StateDurations":
        """Build from the per-rank / per-device state dicts that
        :class:`~repro.core.talp.RegionResult` and the merge layer carry
        (keys sorted, so the construction is deterministic)."""
        ranks = sorted(host_states or {})
        devs = sorted(device_states or {})
        return cls(
            elapsed=float(elapsed),
            useful=[host_states[r]["useful"] for r in ranks] if ranks else None,
            offload=[host_states[r]["offload"] for r in ranks] if ranks else None,
            mpi=[host_states[r]["mpi"] for r in ranks] if ranks else None,
            kernel=[device_states[d]["kernel"] for d in devs] if devs else None,
            memory=[device_states[d]["memory"] for d in devs] if devs else None,
            extras=dict(extras or {}),
        )


# ---------------------------------------------------------------------------
# spec + frame + hierarchy
# ---------------------------------------------------------------------------
# A formula sees the input record and a ``dep(key)`` resolver for other
# metrics of the same hierarchy (memoized, cycle-checked).
Formula = Callable[[StateDurations, Callable[[str], Optional[float]]], Optional[float]]


@dataclass(frozen=True)
class MetricSpec:
    key: str
    display: str
    formula: Formula
    children: Tuple["MetricSpec", ...] = ()
    multiplicative: bool = True
    optional: bool = False


@dataclass
class MetricFrame:
    """Computed metric values of one hierarchy, in hierarchy order."""

    hierarchy: "Hierarchy"
    values: Dict[str, float]
    elapsed: float
    count: int

    def __getitem__(self, key: str) -> float:
        return self.values[key]

    def __contains__(self, key: str) -> bool:
        return key in self.values

    def get(self, key: str, default: Optional[float] = None) -> Optional[float]:
        return self.values.get(key, default)

    def validate(self, tol: float = 1e-9) -> None:
        """Generic multiplicative invariant: every node with multiplicative
        children equals their product (within ``tol``)."""
        for spec in self.hierarchy.walk():
            if spec.key not in self.values:
                continue
            mult = [
                c for c in spec.children
                if c.multiplicative and c.key in self.values
            ]
            if not mult:
                continue
            prod = 1.0
            for c in mult:
                prod *= self.values[c.key]
            if abs(prod - self.values[spec.key]) > tol:
                raise AssertionError(
                    f"{self.hierarchy.name}:{spec.key} "
                    f"{self.values[spec.key]} != product of children {prod}"
                )

    def scalar_fields(self) -> Dict[str, float]:
        """Core metrics (hierarchy order), then ``elapsed`` and the count,
        then optional/extension metrics — the façade-dataclass field layout
        and the report-JSON key order."""
        h = self.hierarchy
        out: Dict[str, float] = {}
        for spec in h.walk():
            if not spec.optional and spec.key in self.values:
                out[spec.key] = self.values[spec.key]
        out["elapsed"] = self.elapsed
        out[h.count_key] = self.count
        for spec in h.walk():
            if spec.optional and spec.key in self.values:
                out[spec.key] = self.values[spec.key]
        return out

    def as_dict(self) -> Dict[str, float]:
        return self.scalar_fields()

    def tree(self):
        """MetricNode view of this frame (paper Figs. 1–3)."""
        from .tree import tree_from_frame

        return tree_from_frame(self)


@dataclass
class Hierarchy:
    """A named multiplicative metric hierarchy (one paper figure)."""

    name: str          # engine id: "pop" / "host" / "device" / ...
    side: str          # report side column: "Host" / "Device"
    count_key: str     # scalar count field: "n_processes" / "n_devices"
    count: Callable[[StateDurations], int]
    root: MetricSpec
    _index: Dict[str, MetricSpec] = field(
        default_factory=dict, init=False, repr=False, compare=False
    )

    def __post_init__(self) -> None:
        for spec in self.walk():
            if spec.key in self._index:
                raise ValueError(f"duplicate metric key {spec.key!r} in {self.name}")
            self._index[spec.key] = spec

    def walk(self) -> Iterator[MetricSpec]:
        """Pre-order walk (parent before children, siblings in order)."""

        def rec(spec: MetricSpec) -> Iterator[MetricSpec]:
            yield spec
            for c in spec.children:
                yield from rec(c)

        yield from rec(self.root)

    def spec(self, key: str) -> MetricSpec:
        return self._index[key]

    def keys(self) -> Tuple[str, ...]:
        return tuple(s.key for s in self.walk())

    def compute(self, sd: StateDurations) -> MetricFrame:
        """Evaluate every formula against one :class:`StateDurations`."""
        values: Dict[str, float] = {}
        resolving: set = set()

        def dep(key: str) -> Optional[float]:
            if key in values:
                return values[key]
            if key in resolving:
                raise RuntimeError(
                    f"metric dependency cycle at {key!r} in hierarchy {self.name}"
                )
            spec = self._index[key]
            resolving.add(key)
            try:
                v = spec.formula(sd, dep)
            finally:
                resolving.discard(key)
            if v is not None:
                values[key] = float(v)
                return values[key]
            if not spec.optional:
                raise ValueError(
                    f"formula for non-optional metric {key!r} returned None"
                )
            return None

        for spec in self.walk():
            dep(spec.key)
        return MetricFrame(
            hierarchy=self, values=values,
            elapsed=sd.elapsed, count=self.count(sd),
        )

    def frame_of(self, obj) -> MetricFrame:
        """Rebuild a frame from any object exposing the metric keys as
        attributes (the façade dataclasses, or a reconstructed payload)."""
        values: Dict[str, float] = {}
        for spec in self.walk():
            v = getattr(obj, spec.key, None)
            if v is not None:
                values[spec.key] = v
        return MetricFrame(
            hierarchy=self,
            values=values,
            elapsed=getattr(obj, "elapsed", 0.0),
            count=getattr(obj, self.count_key, 0),
        )

    def with_child(self, parent_key: str, child: MetricSpec) -> "Hierarchy":
        """Register a new metric under ``parent_key`` — returns a NEW
        hierarchy; compute/validate/tree/report all pick the node up
        automatically. Multiplicative children must complete the parent's
        product; annotation metrics should pass ``multiplicative=False``.
        """
        if parent_key not in self._index:
            raise KeyError(f"no metric {parent_key!r} in hierarchy {self.name}")
        if child.key in self._index:
            raise ValueError(f"metric {child.key!r} already exists in {self.name}")

        def rebuild(spec: MetricSpec) -> MetricSpec:
            children = tuple(rebuild(c) for c in spec.children)
            if spec.key == parent_key:
                children = children + (child,)
            return replace(spec, children=children)

        return Hierarchy(
            name=self.name, side=self.side, count_key=self.count_key,
            count=self.count, root=rebuild(self.root),
        )


# ---------------------------------------------------------------------------
# shared arithmetic — each efficiency form is written once
# ---------------------------------------------------------------------------
def _parallel_efficiency(work: np.ndarray, elapsed: float, n: int) -> float:
    """Σ work / (E · n) — eqs. (3), (6), (7), (9)."""
    return float(np.sum(work)) / (elapsed * n)


def _load_balance(work: np.ndarray) -> float:
    """Σ work / (n · max work) — eqs. (4), (10) and the MPI-level LB."""
    m = float(np.max(work))
    return float(np.sum(work)) / (len(work) * m) if m > 0 else 0.0


def _ratio(num: float, den: float) -> float:
    """num / den, 0 when the denominator vanishes — eqs. (5), (8), (11), (12)."""
    return num / den if den > 0 else 0.0


# ---------------------------------------------------------------------------
# the paper's hierarchies, stated once
# ---------------------------------------------------------------------------
#: Original POP MPI hierarchy (paper §3.3, Fig. 1): PE = LB × CE.
POP = Hierarchy(
    name="pop",
    side="MPI",
    count_key="n_processes",
    count=lambda sd: sd.n_ranks,
    root=MetricSpec(
        "parallel_efficiency", "Parallel Efficiency",
        lambda sd, dep: _parallel_efficiency(sd.useful, sd.elapsed, sd.n_ranks),  # eq. (3)
        children=(
            MetricSpec(
                "load_balance", "Load Balance",
                lambda sd, dep: _load_balance(sd.useful),                         # eq. (4)
            ),
            MetricSpec(
                "communication_efficiency", "Communication Eff.",
                lambda sd, dep: _ratio(float(np.max(sd.useful)), sd.elapsed),     # eq. (5)
            ),
        ),
    ),
)

#: Host hierarchy for accelerated platforms (paper §4.1, Fig. 2):
#: PE_host = MPI_PE × OE_host, with MPI_PE = LB × CE over Useful+Offload.
HOST = Hierarchy(
    name="host",
    side="Host",
    count_key="n_processes",
    count=lambda sd: sd.n_ranks,
    root=MetricSpec(
        "parallel_efficiency", "Parallel Efficiency",
        lambda sd, dep: _parallel_efficiency(sd.useful, sd.elapsed, sd.n_ranks),  # eq. (6)
        children=(
            MetricSpec(
                "mpi_parallel_efficiency", "MPI Parallel Eff.",
                lambda sd, dep: _parallel_efficiency(                             # eq. (7)
                    sd.host_work, sd.elapsed, sd.n_ranks
                ),
                children=(
                    MetricSpec(
                        "communication_efficiency", "Comm. Eff.",
                        lambda sd, dep: _ratio(
                            float(np.max(sd.host_work)), sd.elapsed
                        ),
                    ),
                    MetricSpec(
                        "load_balance", "Load Balance",
                        lambda sd, dep: _load_balance(sd.host_work),
                    ),
                ),
            ),
            MetricSpec(
                "device_offload_efficiency", "Device Offload Eff.",
                lambda sd, dep: _ratio(                                           # eq. (8)
                    float(np.sum(sd.useful)), float(np.sum(sd.host_work))
                ),
            ),
            # TALP self-cost as a fraction of wall-clock — the paper's
            # "lightweight monitoring" claim, measured (fed through
            # ``extras`` by the monitor's overhead accumulator; absent
            # unless self-accounting is enabled).
            MetricSpec(
                "talp_overhead", "TALP Overhead",
                lambda sd, dep: sd.extras.get("talp_overhead"),
                multiplicative=False,
                optional=True,
            ),
        ),
    ),
)

#: Device hierarchy (paper §4.1, Fig. 3): PE = LB × CE × OE, plus the
#: paper's future-work Computational Efficiency branch as an optional
#: annotation node fed through ``extras`` (beyond-paper extension).
DEVICE = Hierarchy(
    name="device",
    side="Device",
    count_key="n_devices",
    count=lambda sd: sd.n_devices,
    root=MetricSpec(
        "parallel_efficiency", "Parallel Efficiency",
        lambda sd, dep: _parallel_efficiency(sd.kernel, sd.elapsed, sd.n_devices),  # eq. (9)
        children=(
            MetricSpec(
                "load_balance", "Load Balance",
                lambda sd, dep: _load_balance(sd.kernel),                           # eq. (10)
            ),
            MetricSpec(
                "communication_efficiency", "Communication Eff.",
                lambda sd, dep: _ratio(                                             # eq. (11)
                    float(np.max(sd.kernel)), float(np.max(sd.device_work))
                ),
            ),
            MetricSpec(
                "orchestration_efficiency", "Orchestration Eff.",
                lambda sd, dep: _ratio(float(np.max(sd.device_work)), sd.elapsed),  # eq. (12)
            ),
            MetricSpec(
                "computational_efficiency", "Computational Eff.",
                lambda sd, dep: sd.extras.get("computational_efficiency"),
                multiplicative=False,
                optional=True,
            ),
        ),
    ),
)

#: POP scalability branch across runs (beyond-paper, §"scalability
#: metrics of several TALP runs"): Global Eff. = Comp. Scalability × PE,
#: with Speedup as a non-multiplicative annotation. Inputs arrive via
#: ``extras``: base_elapsed, resources, base_resources, parallel_efficiency.
SCALABILITY = Hierarchy(
    name="scalability",
    side="Scal",
    count_key="resources",
    count=lambda sd: int(sd.extras.get("resources", 0)),
    root=MetricSpec(
        "global_efficiency", "Global Efficiency",
        lambda sd, dep: _ratio(
            dep("speedup"),
            sd.extras["resources"] / sd.extras["base_resources"],
        ),
        children=(
            MetricSpec(
                "computational_scalability", "Computational Scalability",
                lambda sd, dep: _ratio(
                    dep("global_efficiency"), dep("parallel_efficiency")
                ),
            ),
            MetricSpec(
                "parallel_efficiency", "Parallel Efficiency",
                lambda sd, dep: float(sd.extras["parallel_efficiency"]),
            ),
            MetricSpec(
                "speedup", "Speedup",
                lambda sd, dep: _ratio(sd.extras["base_elapsed"], sd.elapsed),
                multiplicative=False,
            ),
        ),
    ),
)
