"""Step-resolution metric series: one hierarchy frame per region close.

The exporter's polling cadence (:mod:`.exporter`) averages a one-step
load-imbalance spike or a slow offload-efficiency drift into the
cumulative history. This module captures metrics at *step* resolution
instead: a :class:`StepSeriesRecorder` attaches to
:meth:`TalpMonitor.on_region_close <repro.core.talp.TalpMonitor.on_region_close>`
and, for every closed window, computes the per-window host frame from
the close event's state deltas and the device frame by intersecting the
incremental flattened-timeline cache with exactly that window — then
appends one row to a bounded columnar :class:`StepSeries`.

Columns are derived **generically** from the hierarchy specs
(``{hierarchy.name}_{spec.key}`` for every node of every configured
hierarchy), so a metric registered with
:meth:`Hierarchy.with_child <repro.core.hierarchy.Hierarchy.with_child>`
flows into the step series, the per-step trace counters, the merged
job-level table, and the watchdog without touching this module. Rows
additionally carry the raw per-window host state durations
(useful/offload/mpi), which is what lets the merge layer *recompute*
exact job-level host metrics per step instead of averaging per-rank
efficiencies.

The ring is a structured NumPy array: appending a row is a handful of
scalar stores, and the whole series spools as one NPZ entry. The
recorder's hot-path cost is charged to the ``step`` section of the
monitor's :class:`~.overhead.OverheadAccumulator`, so it shows up under
the ``talp_overhead`` report annotation like every other monitor cost.
"""

from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..hierarchy import DEVICE, HOST, Hierarchy, StateDurations
from .. import intervals as ivx

__all__ = [
    "BASE_FIELDS",
    "DEFAULT_HIERARCHIES",
    "StepSeries",
    "StepSeriesRecorder",
    "metric_columns_of",
]

#: Hierarchies recorded by default (matches what the monitor reports).
DEFAULT_HIERARCHIES: Tuple[Hierarchy, ...] = (HOST, DEVICE)

#: Non-metric row fields, in dtype order. ``region`` indexes the interned
#: region-name table; ``useful``/``offload``/``mpi`` are the *per-window*
#: host state deltas (the merge layer rebuilds exact multi-rank host
#: metrics from them).
BASE_FIELDS: Tuple[Tuple[str, str], ...] = (
    ("step", "i8"),
    ("region", "u4"),
    ("t_open", "f8"),
    ("t_close", "f8"),
    ("elapsed", "f8"),
    ("useful", "f8"),
    ("offload", "f8"),
    ("mpi", "f8"),
)


def metric_columns_of(hierarchies: Sequence[Hierarchy]) -> Tuple[str, ...]:
    """Column name per metric node: ``{hierarchy.name}_{spec.key}`` for
    every spec in walk order — ``with_child()`` metrics appear
    automatically."""
    cols: List[str] = []
    for h in hierarchies:
        for spec in h.walk():
            cols.append(f"{h.name}_{spec.key}")
    return tuple(cols)


class StepSeries:
    """Bounded columnar ring of per-step metric rows.

    ``capacity`` bounds memory: once full, the oldest rows are
    overwritten and :attr:`n_dropped` counts what fell off. Metric
    columns hold NaN where a hierarchy produced no value for that step
    (e.g. no device activity yet, or an optional annotation node that
    returned ``None``).
    """

    def __init__(
        self,
        capacity: int = 4096,
        hierarchies: Sequence[Hierarchy] = DEFAULT_HIERARCHIES,
    ):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.hierarchies: Tuple[Hierarchy, ...] = tuple(hierarchies)
        self.metric_columns: Tuple[str, ...] = metric_columns_of(self.hierarchies)
        self.dtype = np.dtype(
            list(BASE_FIELDS) + [(c, "f8") for c in self.metric_columns]
        )
        self._buf = np.zeros(self.capacity, dtype=self.dtype)
        self._n = 0
        # rows that were already dropped before this object existed (set
        # by from_arrays when a spooled ring had wrapped) — pure
        # accounting, the buffer itself is never rotated by it
        self._pre_dropped = 0
        self._region_ids: Dict[str, int] = {}
        self._region_names: List[str] = []

    # -- write ------------------------------------------------------------
    def _intern(self, region: str) -> int:
        rid = self._region_ids.get(region)
        if rid is None:
            rid = len(self._region_names)
            self._region_ids[region] = rid
            self._region_names.append(region)
        return rid

    def append(
        self,
        region: str,
        step: int,
        t_open: float,
        t_close: float,
        useful: float = 0.0,
        offload: float = 0.0,
        mpi: float = 0.0,
        values: Optional[Dict[str, float]] = None,
    ) -> None:
        """Append one row; ``values`` maps metric column names to floats
        (missing columns become NaN, unknown keys are ignored)."""
        row = self._buf[self._n % self.capacity]
        row["step"] = step
        row["region"] = self._intern(region)
        row["t_open"] = t_open
        row["t_close"] = t_close
        row["elapsed"] = t_close - t_open
        row["useful"] = useful
        row["offload"] = offload
        row["mpi"] = mpi
        vals = values or {}
        for c in self.metric_columns:
            v = vals.get(c)
            row[c] = math.nan if v is None else v
        self._n += 1

    # -- read -------------------------------------------------------------
    @property
    def n_total(self) -> int:
        """Rows ever appended (including overwritten ones)."""
        return self._n + self._pre_dropped

    @property
    def n_dropped(self) -> int:
        return self._pre_dropped + max(0, self._n - self.capacity)

    def __len__(self) -> int:
        return min(self._n, self.capacity)

    @property
    def region_names(self) -> Tuple[str, ...]:
        return tuple(self._region_names)

    def region_name(self, rid: int) -> str:
        return self._region_names[int(rid)]

    def rows(self) -> np.ndarray:
        """Retained rows in chronological order (a copy)."""
        if self._n <= self.capacity:
            return self._buf[: self._n].copy()
        i = self._n % self.capacity
        return np.concatenate([self._buf[i:], self._buf[:i]])

    def column(self, name: str, region: Optional[str] = None) -> np.ndarray:
        """One column, optionally restricted to a region's rows."""
        r = self.rows()
        if region is not None:
            r = r[r["region"] == self._region_ids[region]]
        return r[name].copy()

    # -- spool round trip --------------------------------------------------
    def to_arrays(self) -> Dict[str, np.ndarray]:
        """Arrays for an NPZ spool entry: the structured ``rows`` (dtype
        carries the schema) plus the interned ``regions`` name table and
        the total-appended count (for ``n_dropped`` reconstruction)."""
        return {
            "rows": self.rows(),
            "regions": np.asarray(self._region_names, dtype=np.str_),
            "n_total": np.asarray(self._n, dtype=np.int64),
        }

    @classmethod
    def from_arrays(
        cls,
        rows: np.ndarray,
        regions: np.ndarray,
        n_total: Optional[int] = None,
    ) -> "StepSeries":
        """Rebuild from :meth:`to_arrays` output. The metric schema is
        recovered from the structured dtype itself, so a reader does not
        need the writer's (possibly ``with_child``-extended) hierarchy
        objects."""
        rows = np.asarray(rows)
        base = {name for name, _ in BASE_FIELDS}
        self = cls.__new__(cls)
        self.capacity = max(1, len(rows))
        self.hierarchies = ()
        self.metric_columns = tuple(
            n for n in (rows.dtype.names or ()) if n not in base
        )
        self.dtype = rows.dtype
        self._buf = np.array(rows, dtype=rows.dtype)
        # to_arrays() already emitted retained rows chronologically, so
        # the buffer starts unwrapped; any excess of n_total over what is
        # here was dropped by the writer's ring and is pure accounting.
        self._n = len(rows)
        total = int(n_total) if n_total is not None else len(rows)
        self._pre_dropped = max(0, total - len(rows))
        self._region_names = [str(r) for r in np.asarray(regions).tolist()]
        self._region_ids = {r: i for i, r in enumerate(self._region_names)}
        return self

    # -- text view ---------------------------------------------------------
    def as_table(
        self,
        columns: Optional[Sequence[str]] = None,
        max_rows: int = 50,
    ) -> str:
        """Plain-text per-step table (the merge CLI ``--step-series``
        view). ``columns`` defaults to every metric column."""
        cols = list(columns) if columns is not None else list(self.metric_columns)
        header = ["region", "step", "elapsed"] + cols
        lines = ["  ".join(f"{h:>24}" if i > 1 else f"{h:<12}"
                           for i, h in enumerate(header))]
        r = self.rows()
        shown = r if len(r) <= max_rows else r[-max_rows:]
        for row in shown:
            cells = [
                f"{self.region_name(row['region']):<12}",
                f"{int(row['step']):>24d}",
                f"{float(row['elapsed']):>24.6f}",
            ]
            for c in cols:
                v = float(row[c])
                cells.append(f"{'-':>24}" if math.isnan(v) else f"{v:>24.4f}")
            lines.append("  ".join(cells))
        if len(r) > max_rows:
            lines.append(f"... ({len(r) - max_rows} earlier rows not shown)")
        if self.n_dropped:
            lines.append(f"... ({self.n_dropped} rows dropped by ring capacity)")
        return "\n".join(lines)


class StepSeriesRecorder:
    """Attaches a :class:`StepSeries` (and optionally a watchdog) to a
    monitor's region-close hook.

    Per closed window the recorder computes:

      * the **host** frame from the event's per-window state deltas
        (single-rank ``StateDurations`` — exact, no history involved);
      * the **device** frame by intersecting the monitor's incremental
        per-device flattened cache with ``[t_open, t_close]`` — the same
        arrays ``sample()`` uses, so an unchanged timeline is a pure
        cache hit and the per-close cost stays bounded.

    ``regions`` restricts recording to a subset of region names (default:
    every region). The whole callback is charged to the monitor
    overhead accumulator's ``step`` section.
    """

    def __init__(
        self,
        monitor,
        capacity: int = 4096,
        hierarchies: Sequence[Hierarchy] = DEFAULT_HIERARCHIES,
        regions: Optional[Sequence[str]] = None,
        watchdog=None,
    ):
        self.monitor = monitor
        self.series = StepSeries(capacity=capacity, hierarchies=hierarchies)
        self.regions = None if regions is None else frozenset(regions)
        self.watchdog = watchdog
        self._unregister = monitor.on_region_close(self._on_close)

    def close(self) -> None:
        """Detach from the monitor (idempotent)."""
        if self._unregister is not None:
            self._unregister()
            self._unregister = None

    # -- the hot path -----------------------------------------------------
    def _on_close(self, mon, ev) -> None:
        if self.regions is not None and ev.region not in self.regions:
            return
        t0 = mon.overhead.begin()
        try:
            self._record(mon, ev)
        finally:
            mon.overhead.end("step", t0)

    def _record(self, mon, ev) -> None:
        elapsed = ev.elapsed
        if elapsed <= 0:
            return
        # Drain the backend's activity buffers so the just-closed window's
        # kernel/memory records are in the timelines (region close does not
        # flush by itself; sample()/finalize() do).
        mon._flush_backend()
        values: Dict[str, float] = {}
        for h in self.series.hierarchies:
            if h.name == "host":
                sd = StateDurations(
                    elapsed=elapsed,
                    useful=[ev.useful],
                    offload=[ev.offload],
                    mpi=[ev.mpi],
                )
            elif h.name == "device":
                if not mon.devices:
                    continue
                flats = mon._device_flats()
                kernels: List[float] = []
                memories: List[float] = []
                for _dev, (kern, mem) in sorted(flats.items()):
                    kernels.append(
                        ivx.window_total(kern, ev.t_open, ev.t_close))
                    memories.append(
                        ivx.window_total(mem, ev.t_open, ev.t_close))
                if not kernels:
                    continue
                extras: Dict[str, float] = {}
                ce = mon.computational_efficiency(flats)
                if ce is not None:
                    extras["computational_efficiency"] = ce
                sd = StateDurations(
                    elapsed=elapsed,
                    kernel=kernels,
                    memory=memories,
                    extras=extras,
                )
            else:
                # Unknown hierarchy family: nothing to feed it per step.
                continue
            frame = h.compute(sd)
            for key, val in frame.values.items():
                values[f"{h.name}_{key}"] = val
        self.series.append(
            region=ev.region,
            step=ev.index,
            t_open=ev.t_open,
            t_close=ev.t_close,
            useful=ev.useful,
            offload=ev.offload,
            mpi=ev.mpi,
            values=values,
        )
        if self.watchdog is not None:
            self.watchdog.observe(
                region=ev.region, step=ev.index, t=ev.t_close, values=values
            )
