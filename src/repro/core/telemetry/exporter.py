"""Runtime metric stream — TALP's "available at runtime" promise, wired.

:class:`TelemetryExporter` wraps :meth:`TalpMonitor.sample_result` into a
bounded ring buffer of timestamped snapshots and publishes each one as

  * a **JSONL stream** (one self-contained JSON object per sample, to a
    path or any writable file object — a dashboard tails it), and
  * **Prometheus text-format exposition** (opt-in stdlib HTTP server,
    ``GET /metrics``), the format MPCDF-style production monitoring
    scrapes.

Metric names are derived *generically* from the
:class:`~repro.core.hierarchy.Hierarchy` specs: a JSONL record carries
each region's ``frame.scalar_fields()`` keyed by hierarchy name, and a
Prometheus family is ``talp_{hierarchy}_{spec key}`` with a ``region``
label. Nothing here enumerates metrics — a metric registered with
``Hierarchy.with_child()`` appears in both outputs with no exporter
changes, exactly like it appears in the text/JSON reports.

An attached :class:`~.watchdog.EfficiencyWatchdog` is published too:
each JSONL record carries its ``summary()`` (watched metrics, event
count, currently-firing detectors) and the exposition gains a
``talp_watchdog_events_total`` counter plus one ``talp_watchdog_firing``
gauge per firing (region, metric).
"""

from __future__ import annotations

import json
import threading
import time
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Tuple, Union

from ..hierarchy import MetricFrame
from ..talp import RegionResult, TalpMonitor, TalpResult
from . import overhead as _ovh

__all__ = ["TelemetrySnapshot", "TelemetryExporter", "result_frames"]

_PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


def result_frames(rr: RegionResult) -> List[MetricFrame]:
    """Metric frames of one region result — façade dataclass or raw
    :class:`MetricFrame` alike (``with_child`` flows pass frames)."""
    frames = []
    for obj in (rr.host, rr.device):
        if obj is None:
            continue
        frames.append(obj if isinstance(obj, MetricFrame) else obj.frame())
    return frames


@dataclass
class TelemetrySnapshot:
    """One ring-buffer entry: a non-destructive all-regions result plus
    the monitor-clock and wall-clock instants it was taken at."""

    seq: int
    t: float       # monitor clock (same domain as region windows/devices)
    wall: float    # unix epoch, for cross-host correlation
    result: TalpResult


class TelemetryExporter:
    """Bounded ring buffer of monitor snapshots with JSONL + Prometheus
    publication.

    ``jsonl`` may be a path (opened append, line-buffered intent — each
    record is flushed) or any object with ``write``; pass
    ``prometheus=True``-style opt-in by calling :meth:`serve` (port 0
    binds an ephemeral port and returns it). ``close()`` is idempotent
    and leaves the ring readable.
    """

    def __init__(
        self,
        monitor: TalpMonitor,
        capacity: int = 256,
        jsonl: Optional[Union[str, "object"]] = None,
        watchdog=None,
    ):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.monitor = monitor
        self.capacity = capacity
        self.watchdog = watchdog
        self._ring: List[TelemetrySnapshot] = []
        self._seq = 0
        self._lock = threading.Lock()
        self._jsonl_owned = False
        self._jsonl = None
        if jsonl is not None:
            if hasattr(jsonl, "write"):
                self._jsonl = jsonl
            else:
                self._jsonl = open(jsonl, "a")
                self._jsonl_owned = True
        self._http = None
        self._http_thread = None

    # ------------------------------------------------------------------
    # sampling
    # ------------------------------------------------------------------
    def sample(self) -> TelemetrySnapshot:
        """Take one snapshot: ring-buffer it and publish to the JSONL
        stream (the Prometheus endpoint always serves the latest)."""
        with _ovh.section("sample"):
            t = self.monitor.clock()
            result = self.monitor.sample_result()
            with self._lock:
                snap = TelemetrySnapshot(
                    seq=self._seq, t=t, wall=time.time(), result=result
                )
                self._seq += 1
                self._ring.append(snap)
                if len(self._ring) > self.capacity:
                    del self._ring[: len(self._ring) - self.capacity]
            if self._jsonl is not None:
                with _ovh.section("export"):
                    self._jsonl.write(
                        json.dumps(self.jsonl_record(snap),
                                   separators=(",", ":")) + "\n"
                    )
                    if hasattr(self._jsonl, "flush"):
                        self._jsonl.flush()
            return snap

    @property
    def last(self) -> Optional[TelemetrySnapshot]:
        with self._lock:
            return self._ring[-1] if self._ring else None

    def snapshots(self) -> List[TelemetrySnapshot]:
        with self._lock:
            return list(self._ring)

    def trace_samples(self) -> List[Tuple[float, TalpResult]]:
        """(monitor-clock t, result) pairs — the ``samples`` input of the
        Chrome-trace counter tracks."""
        return [(s.t, s.result) for s in self.snapshots()]

    # ------------------------------------------------------------------
    # JSONL
    # ------------------------------------------------------------------
    def jsonl_record(self, snap: TelemetrySnapshot) -> Dict:
        """One self-contained JSON object per sample. Region metrics are
        each frame's ``scalar_fields()`` keyed by hierarchy name — spec
        keys verbatim, so stream consumers and report JSON agree."""
        regions: Dict[str, Dict] = {}
        for rname in sorted(snap.result.regions):
            rr = snap.result.regions[rname]
            entry: Dict[str, object] = {"elapsed": rr.elapsed}
            for frame in result_frames(rr):
                entry[frame.hierarchy.name] = frame.scalar_fields()
            regions[rname] = entry
        record = {
            "seq": snap.seq,
            "t": snap.t,
            "wall": snap.wall,
            "name": snap.result.name,
            "regions": regions,
        }
        cov = getattr(snap.result, "rank_coverage", None)
        if cov is not None:
            # job-level snapshots from a tolerant merge carry their
            # partial-rank annotation into the stream
            record["rank_coverage"] = (
                cov.as_dict() if hasattr(cov, "as_dict") else cov
            )
        if self.watchdog is not None:
            record["watchdog"] = self.watchdog.summary()
        return record

    # ------------------------------------------------------------------
    # Prometheus text exposition
    # ------------------------------------------------------------------
    def _families(
        self, snap: TelemetrySnapshot
    ) -> Iterator[Tuple[str, str, List[Tuple[str, float]]]]:
        """(family name, help text, [(label string, value)]) groups, one
        family per (hierarchy, scalar field) across regions."""
        fams: Dict[str, Tuple[str, List[Tuple[str, float]]]] = {}
        for rname in sorted(snap.result.regions):
            rr = snap.result.regions[rname]
            labels = f'{{region="{rname}",trace="{snap.result.name}"}}'
            for frame in result_frames(rr):
                h = frame.hierarchy
                displays = {s.key: s.display for s in h.walk()}
                for key, value in frame.scalar_fields().items():
                    fam = f"talp_{h.name}_{key}"
                    help_text = displays.get(
                        key,
                        "elapsed seconds" if key == "elapsed" else key,
                    )
                    fams.setdefault(fam, (help_text, []))[1].append(
                        (labels, float(value))
                    )
        for fam in sorted(fams):
            help_text, rows = fams[fam]
            yield fam, help_text, rows

    def prometheus_text(
        self, snap: Optional[TelemetrySnapshot] = None
    ) -> str:
        """Prometheus text-format exposition of one snapshot (latest by
        default; empty exposition before the first sample)."""
        snap = snap if snap is not None else self.last
        if snap is None:
            return "# no samples yet\n"
        out: List[str] = []
        for fam, help_text, rows in self._families(snap):
            out.append(f"# HELP {fam} {help_text}")
            out.append(f"# TYPE {fam} gauge")
            for labels, value in rows:
                out.append(f"{fam}{labels} {value:.17g}")
        out.append(f"# HELP talp_sample_seq sample sequence number")
        out.append(f"# TYPE talp_sample_seq counter")
        out.append(
            f'talp_sample_seq{{trace="{snap.result.name}"}} {snap.seq}'
        )
        if self.watchdog is not None:
            s = self.watchdog.summary()
            out.append(
                "# HELP talp_watchdog_events_total anomaly events emitted"
            )
            out.append("# TYPE talp_watchdog_events_total counter")
            out.append(
                f'talp_watchdog_events_total'
                f'{{trace="{snap.result.name}"}} {s["n_events"]}'
            )
            out.append(
                "# HELP talp_watchdog_firing detector currently firing "
                "(1 per firing region/metric)"
            )
            out.append("# TYPE talp_watchdog_firing gauge")
            for f in s["firing"]:
                out.append(
                    f'talp_watchdog_firing{{region="{f["region"]}",'
                    f'metric="{f["metric"]}",'
                    f'trace="{snap.result.name}"}} 1'
                )
        return "\n".join(out) + "\n"

    @property
    def port(self) -> Optional[int]:
        """Bound HTTP port (``None`` until :meth:`serve` has been
        called) — with ``serve(port=0)`` this is how tests discover the
        ephemeral port."""
        return self._http.server_address[1] if self._http is not None else None

    def serve(self, port: int = 0, host: str = "127.0.0.1") -> int:
        """Start the opt-in stdlib HTTP endpoint (``GET /metrics``) in a
        daemon thread; returns the bound port (pass 0 for ephemeral)."""
        if self._http is not None:
            return self._http.server_address[1]
        from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

        exporter = self

        class _Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 (stdlib handler API)
                if self.path not in ("/", "/metrics"):
                    self.send_response(404)
                    self.end_headers()
                    return
                body = exporter.prometheus_text().encode("utf-8")
                self.send_response(200)
                self.send_header("Content-Type", _PROM_CONTENT_TYPE)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *args):  # silence per-request stderr
                pass

        self._http = ThreadingHTTPServer((host, port), _Handler)
        self._http_thread = threading.Thread(
            target=self._http.serve_forever, name="talp-prometheus",
            daemon=True,
        )
        self._http_thread.start()
        return self._http.server_address[1]

    def close(self) -> None:
        if self._http is not None:
            self._http.shutdown()
            self._http.server_close()
            self._http = None
            self._http_thread = None
        if self._jsonl is not None and self._jsonl_owned:
            self._jsonl.close()
        self._jsonl = None
        self._jsonl_owned = False

    def __enter__(self) -> "TelemetryExporter":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
