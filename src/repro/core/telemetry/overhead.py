"""TALP self-overhead accounting — the "lightweight" claim, measured.

The paper sells TALP as *lightweight monitoring*; production monitoring
systems (MPCDF's HPC monitor, arXiv:1909.11704) treat the monitor's own
cost as a first-class metric, because an observability layer whose price
is unknown cannot be left on in production. This module instruments the
monitor's hot paths with monotonic-clock accumulators:

  * ``ingest``  — backend flush + columnar record ingestion,
  * ``flush``   — a backend draining its own activity buffers,
  * ``compact`` — pending-row folds into the flattened interval arrays,
  * ``flatten`` — per-device flattened-pair construction at sample time,
  * ``sample``  — online snapshot construction (includes nested work),
  * ``step``    — per-region-close step-series capture (+ watchdog),
  * ``spool``   — spool-payload serialization + atomic publish,
  * ``export``  — Chrome-trace / metric-stream rendering.

Sections may nest (a ``sample`` triggers ``flatten`` which may trigger
``compact``); per-section totals are *inclusive* while
:attr:`OverheadAccumulator.total` counts only outermost sections, so the
wall-clock fraction never double-counts nested work.

One accumulator is installed process-globally (every
:class:`~repro.core.talp.TalpMonitor` installs its own at construction;
the most recently installed wins — the one-monitor-per-process reality
of a rank). Timing a section when no accumulator is installed costs a
global load and a ``None`` check, nothing else. The measured fraction
surfaces as the optional ``talp_overhead`` annotation node of the HOST
hierarchy (see :data:`repro.core.hierarchy.HOST`).
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Callable, Dict, Optional

__all__ = [
    "SECTIONS",
    "OverheadAccumulator",
    "install",
    "current",
    "section",
]

#: Known hot-path section names (free-form names are accepted too).
SECTIONS = (
    "ingest", "flush", "compact", "flatten", "sample", "step", "spool", "export",
)


class OverheadAccumulator:
    """Per-section monotonic-clock time accumulator with nesting-aware
    wall-clock total.

    ``totals[section]`` is inclusive (nested sections count toward their
    parents as well as themselves); :attr:`total` sums only sections
    entered at depth 0, so ``total / elapsed`` is a true wall-clock
    fraction. The clock is always a *real* monotonic clock — monitors
    driven by synthetic test clocks still measure their real cost.
    """

    __slots__ = ("totals", "counts", "clock", "_depth", "_outer_total")

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.totals: Dict[str, float] = {}
        self.counts: Dict[str, int] = {}
        self.clock = clock
        self._depth = 0
        self._outer_total = 0.0

    # -- explicit begin/end (hot-path inline form) -----------------------
    def begin(self) -> float:
        self._depth += 1
        return self.clock()

    def end(self, name: str, t0: float) -> float:
        dt = self.clock() - t0
        self._depth -= 1
        self.totals[name] = self.totals.get(name, 0.0) + dt
        self.counts[name] = self.counts.get(name, 0) + 1
        if self._depth == 0:
            self._outer_total += dt
        return dt

    @contextmanager
    def section(self, name: str):
        t0 = self.begin()
        try:
            yield self
        finally:
            self.end(name, t0)

    # -- results ----------------------------------------------------------
    @property
    def total(self) -> float:
        """Outermost-section wall-clock seconds (nesting not double
        counted)."""
        return self._outer_total

    def fraction(self, elapsed: float) -> Optional[float]:
        """Monitor cost as a fraction of ``elapsed`` wall-clock seconds
        (``None`` when the window is empty — the annotation node then
        vanishes from every report)."""
        if elapsed <= 0:
            return None
        return self._outer_total / elapsed

    def as_dict(self) -> Dict[str, object]:
        return {
            "total_s": self._outer_total,
            "sections": dict(self.totals),
            "counts": dict(self.counts),
        }

    def reset(self) -> None:
        self.totals.clear()
        self.counts.clear()
        self._depth = 0
        self._outer_total = 0.0


# ---------------------------------------------------------------------------
# process-global installation
# ---------------------------------------------------------------------------
_current: Optional[OverheadAccumulator] = None


def install(acc: Optional[OverheadAccumulator]) -> Optional[OverheadAccumulator]:
    """Install ``acc`` as the process-global accumulator; returns the
    previously installed one (restore it to scope a measurement)."""
    global _current
    prev = _current
    _current = acc
    return prev


def current() -> Optional[OverheadAccumulator]:
    return _current


@contextmanager
def section(name: str):
    """Time a section against the installed accumulator; a no-op (beyond
    one global load) when none is installed."""
    acc = _current
    if acc is None:
        yield None
        return
    t0 = acc.begin()
    try:
        yield acc
    finally:
        acc.end(name, t0)
