"""Chrome trace-event export — the machine-readable execution trace.

The paper leans on execution traces as "a visual confirmation that the
reported metrics are consistent with the observed behavior"; the ASCII
:mod:`repro.core.traceview` gives the in-terminal check, this module
emits the same timeline in the `Chrome trace event format
<https://docs.google.com/document/d/1CvAClvFfyA5R-PhYUmn5OOQtYMH4h6I0nSsKchNAySU>`_
so any run opens directly in Perfetto (`ui.perfetto.dev`) or
``chrome://tracing``:

  * one lane per host **rank** (pid 1): Useful / Offload / MPI slices
    (synthesized proportionally from the state durations, in recorded
    order — the same convention as ``traceview``),
  * one lane per **device** (pid 2): flattened Kernel / Memory slices —
    exact, straight from the columnar interval arrays,
  * one **regions** lane (pid 3): ``B``/``E`` begin/end markers per
    monitored region,
  * **counter tracks** (pid 4): the hierarchy metrics (PE, LB, CE,
    OE, …) over time, names derived generically from the
    :class:`~repro.core.hierarchy.Hierarchy` specs. When a
    step-resolution :class:`~.stepseries.StepSeries` is attached the
    counters are *per region close* (one point per step, at the window's
    close timestamp) instead of the exporter's polling cadence — a
    one-step spike stays visible,
  * **anomaly markers** (pid 5): instant events (``"ph":"i"``) from the
    :class:`~.watchdog.EfficiencyWatchdog`, one per emitted anomaly,
    carrying the observed/baseline/z payload and the attribution path in
    ``args``.

The slice generator is **vectorized**: interval arrays (the
``ColumnStore``/``flatten()`` output) become JSON event lines through
whole-array NumPy string formatting — no per-record Python loop, no
per-event dict. Two number policies, chosen per field:

  * ``ts`` is quantized to integer **nanoseconds** and emitted as
    ``<ns>e-3`` µs (the resolution Perfetto itself stores); integer
    formatting is a cheap C loop, and ``rint`` is monotone so lane
    ordering survives quantization.
  * ``dur`` is **exact**: NumPy's shortest round-trip float repr
    (C-level dragon4, ``astype("U32")``) survives a JSON round trip
    bit-for-bit, so the exported durations *are* the flattened interval
    durations the metrics were computed from (export is a view, not a
    recomputation — per-lane duration sums match ``StateDurations``).

:func:`export_trace_reference` retains the naive one-dict-per-event
exporter as the correctness oracle and benchmark baseline
(``benchmarks/merge_bench.py`` gates the vectorized path ≥5× against
it); :func:`validate_chrome_trace` is the structural validator the test
suite and CI share. CLI: ``python -m repro.core.telemetry.traceexport
--validate trace.json``.
"""

from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

from .. import intervals as ivx
from ..hierarchy import MetricFrame
from ..states import DeviceActivity, DeviceTimeline, Trace
from ..talp import RegionResult, TalpMonitor, TalpResult
from . import overhead as _ovh

__all__ = [
    "PID_HOST",
    "PID_DEVICE",
    "PID_REGIONS",
    "PID_COUNTERS",
    "PID_ANOMALIES",
    "slice_lines",
    "slice_events_loop",
    "quantize_ts_us",
    "export_trace",
    "export_trace_reference",
    "export_result",
    "export_monitor",
    "export_job",
    "validate_chrome_trace",
    "main",
]

#: Lane group ids (Chrome "processes"); one tid per rank/device inside.
PID_HOST = 1
PID_DEVICE = 2
PID_REGIONS = 3
PID_COUNTERS = 4
PID_ANOMALIES = 5

_US = 1e6  # trace-event timestamps are microseconds

#: host-state slice order + names (recorded order, like traceview)
_HOST_SLICES = (("useful", "Useful"), ("offload", "Offload"), ("mpi", "MPI"))


def _fmt_f64(a: np.ndarray) -> np.ndarray:
    """Whole-array exact float formatting: NumPy's C-level dragon4 emits
    the shortest repr that round-trips float64 exactly through JSON, so
    parsed values equal the source values bit-for-bit."""
    return np.asarray(a, dtype=np.float64).astype("U32")


def _fmt_ts_ns(ts_us: np.ndarray) -> np.ndarray:
    """Whole-array timestamp formatting: integer-nanosecond mantissas
    (``"ts":<ns>`` + the constant ``e-3`` suffix appended by the caller
    = µs). Integer→string is ~3× cheaper than exact float formatting,
    and ``rint`` is monotone, so quantization never reorders a lane."""
    ns = np.rint(np.asarray(ts_us, dtype=np.float64) * 1e3)
    return ns.astype(np.int64).astype("U20")


def quantize_ts_us(ts_us):
    """The parsed value of an emitted timestamp: ``<ns>e-3`` parses to
    exactly ``rint(ts*1e3)/1e3`` (both are the correctly rounded double
    of the same exact decimal). Exposed so the reference exporter and
    the tests model emission with the same arithmetic."""
    return np.rint(np.asarray(ts_us, dtype=np.float64) * 1e3) / 1e3


def _slice_line_array(
    name: str, cat: str, pid: int, tid: int, iv, t0: float = 0.0
) -> np.ndarray:
    """One complete-event (``"ph":"X"``) JSON line per interval as a
    fixed-width string array, generated vectorized from the (N, 2)
    interval array — no per-event Python work at all."""
    iv = np.asarray(iv, dtype=np.float64).reshape(-1, 2)
    if len(iv) == 0:
        return np.empty(0, dtype="U1")
    ts = (iv[:, 0] - t0) * _US
    dur = (iv[:, 1] - iv[:, 0]) * _US
    head = (
        f'{{"name":{json.dumps(name)},"cat":{json.dumps(cat)},"ph":"X",'
        f'"pid":{int(pid)},"tid":{int(tid)},"ts":'
    )
    lines = np.char.add(head, _fmt_ts_ns(ts))
    lines = np.char.add(lines, 'e-3,"dur":')
    lines = np.char.add(lines, _fmt_f64(dur))
    lines = np.char.add(lines, "}")
    return lines


def slice_lines(
    name: str, cat: str, pid: int, tid: int, iv, t0: float = 0.0
) -> List[str]:
    """List-of-lines view of :func:`_slice_line_array` (the only
    per-event Python object creation is the final ``tolist()``)."""
    return _slice_line_array(name, cat, pid, tid, iv, t0).tolist()


def _device_lane_order(kern, mem) -> np.ndarray:
    """Stable time order over the concatenated kernel+memory slices of
    one device lane (kernel wins start-time ties) — Chrome lanes expect
    monotonically ordered events."""
    starts = np.concatenate([
        np.asarray(kern, dtype=np.float64).reshape(-1, 2)[:, 0],
        np.asarray(mem, dtype=np.float64).reshape(-1, 2)[:, 0],
    ])
    return np.argsort(starts, kind="stable")


def _device_lane_lines(
    dev: int, kern, mem, t0: float
) -> List[str]:
    """Kernel + Memory slices of one device lane, time-ordered. The
    merge happens on the raw (N, 2) float intervals *before* formatting
    (an 8-byte gather, not a full-line string gather), then a single
    format pass emits both kinds — the kind-dependent name/cat moves to
    a per-element tail selected with ``np.where``."""
    kern = np.asarray(kern, dtype=np.float64).reshape(-1, 2)
    mem = np.asarray(mem, dtype=np.float64).reshape(-1, 2)
    n_k, n_m = len(kern), len(mem)
    if n_k + n_m == 0:
        return []
    order = _device_lane_order(kern, mem)
    iv = np.concatenate([kern, mem])[order]
    is_kern = (np.arange(n_k + n_m) < n_k)[order]
    ts = (iv[:, 0] - t0) * _US
    dur = (iv[:, 1] - iv[:, 0]) * _US
    head = f'{{"ph":"X","pid":{PID_DEVICE},"tid":{int(dev)},"ts":'
    tails = np.where(
        is_kern,
        ',"name":"Kernel","cat":"device"}',
        ',"name":"Memory","cat":"device"}',
    )
    lines = np.char.add(head, _fmt_ts_ns(ts))
    lines = np.char.add(lines, 'e-3,"dur":')
    lines = np.char.add(lines, _fmt_f64(dur))
    lines = np.char.add(lines, tails)
    return lines.tolist()


def slice_events_loop(
    name: str, cat: str, pid: int, tid: int, iv, t0: float = 0.0
) -> List[Dict]:
    """Retained per-event reference: one Python dict per slice (the shape
    every naive exporter has). Kept as the oracle + benchmark baseline
    for :func:`slice_lines`; not used on any production path."""
    out = []
    for s, e in np.asarray(iv, dtype=np.float64).reshape(-1, 2):
        ts = (float(s) - t0) * _US
        out.append(
            {
                "name": name, "cat": cat, "ph": "X",
                "pid": int(pid), "tid": int(tid),
                "ts": float(quantize_ts_us(ts)),
                "dur": (float(e) - float(s)) * _US,
            }
        )
    return out


# ---------------------------------------------------------------------------
# lane construction helpers
# ---------------------------------------------------------------------------
def _host_state_intervals(
    states: Dict[str, float], t0: float
) -> List[Tuple[str, np.ndarray]]:
    """Proportional (name, 1-interval array) slices for one rank, in
    recorded order starting at ``t0`` — durations only, like traceview."""
    out = []
    cursor = t0
    for key, display in _HOST_SLICES:
        dur = float(states.get(key, 0.0))
        if dur > 0:
            out.append((display, np.array([[cursor, cursor + dur]])))
            cursor += dur
    return out


def _device_lane_intervals(
    tl: DeviceTimeline, window: Optional[Tuple[float, float]] = None
) -> Tuple[np.ndarray, np.ndarray]:
    """(kernel, memory-minus-kernel) flattened arrays for one device —
    exactly the arrays the metrics pipeline computes from."""
    kern = tl.kind_intervals(DeviceActivity.KERNEL)
    mem = ivx.subtract(tl.kind_intervals(DeviceActivity.MEMORY), kern)
    if window is not None:
        kern = ivx.clip(kern, *window)
        mem = ivx.clip(mem, *window)
    return kern, mem


def _synthetic_device_intervals(
    states: Dict[str, float], t0: float
) -> Tuple[np.ndarray, np.ndarray]:
    """Proportional device slices from reduced state durations (fallback
    when no raw timeline is attached): kernel first, then memory."""
    k = float(states.get("kernel", 0.0))
    m = float(states.get("memory", 0.0))
    kern = np.array([[t0, t0 + k]]) if k > 0 else ivx.EMPTY
    mem = np.array([[t0 + k, t0 + k + m]]) if m > 0 else ivx.EMPTY
    return kern, mem


def _meta_line(name: str, pid: int, value: str, tid: int = 0) -> str:
    ev = {"name": name, "ph": "M", "pid": pid, "tid": tid,
          "args": {"name": value}}
    return json.dumps(ev, separators=(",", ":"))


def _region_marker_lines(
    region_windows: Dict[str, np.ndarray], t0: float,
    pid: int = PID_REGIONS, tid: int = 0,
) -> List[str]:
    """Paired ``B``/``E`` begin/end markers, ordered so nesting is valid:
    at equal timestamps ends precede begins, longer regions open first
    and inner regions close first."""
    evs: List[Tuple[float, int, float, str, str]] = []
    for name, iv in region_windows.items():
        for s, e in np.asarray(iv, dtype=np.float64).reshape(-1, 2):
            dur = float(e - s)
            evs.append((float(quantize_ts_us((s - t0) * _US)), 1, -dur, name, "B"))
            evs.append((float(quantize_ts_us((e - t0) * _US)), 0, dur, name, "E"))
    evs.sort(key=lambda t: (t[0], t[1], t[2]))
    return [
        json.dumps(
            {"name": name, "cat": "region", "ph": ph, "pid": pid,
             "tid": tid, "ts": ts},
            separators=(",", ":"),
        )
        for ts, _, _, name, ph in evs
    ]


def _result_frames(rr: RegionResult) -> List[MetricFrame]:
    """Metric frames of one region result, façade or raw frame alike —
    every downstream naming walks ``frame.hierarchy``, so metrics
    registered with ``with_child`` flow through automatically."""
    frames = []
    for obj in (rr.host, rr.device):
        if obj is None:
            continue
        frames.append(obj if isinstance(obj, MetricFrame) else obj.frame())
    return frames


def _counter_lines(
    samples: Sequence[Tuple[float, TalpResult]], t0: float,
    pid: int = PID_COUNTERS,
) -> List[str]:
    """One multi-series counter event per (sample, region, hierarchy) —
    series names are the hierarchy spec keys."""
    lines = []
    for t, res in samples:
        ts = float(quantize_ts_us((float(t) - t0) * _US))
        for rname in sorted(res.regions):
            rr = res.regions[rname]
            for frame in _result_frames(rr):
                args = {
                    spec.key: frame.values[spec.key]
                    for spec in frame.hierarchy.walk()
                    if spec.key in frame.values
                }
                if not args:
                    continue
                lines.append(
                    json.dumps(
                        {
                            "name": f"talp:{frame.hierarchy.name}:{rname}",
                            "ph": "C", "pid": pid, "tid": 0, "ts": ts,
                            "args": args,
                        },
                        separators=(",", ":"),
                    )
                )
    return lines


def _step_counter_lines(
    step_series, t0: float, pid: int = PID_COUNTERS
) -> List[str]:
    """One multi-series counter event per (step row, hierarchy), at the
    window's *close* timestamp — step-resolution counter tracks. Series
    are grouped by the column's hierarchy prefix so the counter names
    match the cadence-sampled ones (``talp:{hierarchy}:{region}``)."""
    lines: List[str] = []
    # hierarchy name -> its metric columns, preserving column order.
    groups: Dict[str, List[Tuple[str, str]]] = {}
    for col in step_series.metric_columns:
        hname, _, key = col.partition("_")
        groups.setdefault(hname, []).append((col, key))
    for row in step_series.rows():
        ts = float(quantize_ts_us((float(row["t_close"]) - t0) * _US))
        rname = step_series.region_name(row["region"])
        for hname, cols in groups.items():
            args = {
                key: float(row[col])
                for col, key in cols
                if not np.isnan(row[col])
            }
            if not args:
                continue
            lines.append(
                json.dumps(
                    {
                        "name": f"talp:{hname}:{rname}",
                        "ph": "C", "pid": pid, "tid": 0, "ts": ts,
                        "args": args,
                    },
                    separators=(",", ":"),
                )
            )
    return lines


def _anomaly_lines(
    anomalies, t0: float, pid: int = PID_ANOMALIES
) -> List[str]:
    """Instant events (``"ph":"i"``, process-scoped) — one marker per
    watchdog anomaly, so degradations are visually pinned on the trace
    timeline. Accepts :class:`~.watchdog.AnomalyEvent` objects or their
    ``as_dict()`` payloads."""
    lines: List[str] = []
    for ev in anomalies:
        d = ev.as_dict() if hasattr(ev, "as_dict") else dict(ev)
        ts = float(quantize_ts_us((float(d["t"]) - t0) * _US))
        lines.append(
            json.dumps(
                {
                    "name": f"talp:anomaly:{d['hierarchy']}:{d['metric']}",
                    "cat": "anomaly", "ph": "i", "s": "p",
                    "pid": pid, "tid": 0, "ts": ts,
                    "args": {
                        "region": d["region"],
                        "step": d["step"],
                        "observed": d["observed"],
                        "baseline_mean": d["baseline_mean"],
                        "z": d["z"],
                        "direction": d["direction"],
                        "attribution": " -> ".join(
                            a["metric"] for a in d.get("attribution", ())
                        ),
                    },
                },
                separators=(",", ":"),
            )
        )
    return lines


def _assemble(
    lines: List[str], name: str, other: Optional[Dict] = None
) -> str:
    meta: Dict[str, object] = {"generator": "repro-talp", "trace": name}
    if other:
        meta.update(other)
    return (
        '{"traceEvents":[' + ",".join(lines) + '],"displayTimeUnit":"ms",'
        '"otherData":' + json.dumps(meta, separators=(",", ":")) + "}"
    )


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------
def _build(
    name: str,
    t0: float,
    host_states: Dict[int, Dict[str, float]],
    device_lanes: Dict[int, Tuple[np.ndarray, np.ndarray]],
    region_windows: Dict[str, np.ndarray],
    samples: Optional[Sequence[Tuple[float, TalpResult]]] = None,
    step_series=None,
    anomalies=None,
    other: Optional[Dict] = None,
) -> str:
    lines: List[str] = [
        _meta_line("process_name", PID_HOST, "host ranks"),
        _meta_line("process_name", PID_DEVICE, "devices"),
    ]
    if region_windows:
        lines.append(_meta_line("process_name", PID_REGIONS, "talp regions"))
    if samples or (step_series is not None and len(step_series)):
        lines.append(_meta_line("process_name", PID_COUNTERS, "talp metrics"))
    if anomalies:
        lines.append(_meta_line("process_name", PID_ANOMALIES, "talp anomalies"))
    for rank in sorted(host_states):
        lines.append(_meta_line("thread_name", PID_HOST, f"rank {rank}", rank))
        for display, iv in _host_state_intervals(host_states[rank], t0):
            lines.extend(slice_lines(display, "host", PID_HOST, rank, iv, t0))
    for dev in sorted(device_lanes):
        kern, mem = device_lanes[dev]
        lines.append(_meta_line("thread_name", PID_DEVICE, f"dev {dev}", dev))
        lines.extend(_device_lane_lines(dev, kern, mem, t0))
    if region_windows:
        lines.extend(_region_marker_lines(region_windows, t0))
    if step_series is not None and len(step_series):
        # Step-resolution counters supersede the polling-cadence ones:
        # one point per region close, nothing averaged away.
        lines.extend(_step_counter_lines(step_series, t0))
    elif samples:
        lines.extend(_counter_lines(samples, t0))
    if anomalies:
        lines.extend(_anomaly_lines(anomalies, t0))
    return _assemble(lines, name, other=other)


def export_trace(
    trace: Trace,
    samples: Optional[Sequence[Tuple[float, TalpResult]]] = None,
) -> str:
    """Render a :class:`~repro.core.states.Trace` (host timelines +
    device record timelines) as Chrome trace JSON."""
    with _ovh.section("export"):
        if trace.window is not None:
            t0, t1 = trace.window
        else:
            t0, t1 = 0.0, trace.elapsed
        host_states = {r: h.as_dict() for r, h in trace.hosts.items()}
        device_lanes = {
            d: _device_lane_intervals(tl, (t0, t1))
            for d, tl in trace.devices.items()
        }
        return _build(trace.name, t0, host_states, device_lanes, {}, samples)


def export_trace_reference(trace: Trace) -> str:
    """Retained per-event reference exporter: identical event stream to
    :func:`export_trace`, built one dict at a time and serialized one
    event at a time — the shape every naive/streaming exporter has.
    Oracle + benchmark baseline; not on any production path."""
    if trace.window is not None:
        t0, t1 = trace.window
    else:
        t0, t1 = 0.0, trace.elapsed
    events: List[Dict] = [
        json.loads(_meta_line("process_name", PID_HOST, "host ranks")),
        json.loads(_meta_line("process_name", PID_DEVICE, "devices")),
    ]
    for rank in sorted(trace.hosts):
        events.append(
            json.loads(_meta_line("thread_name", PID_HOST, f"rank {rank}", rank))
        )
        for display, iv in _host_state_intervals(
            trace.hosts[rank].as_dict(), t0
        ):
            events.extend(slice_events_loop(display, "host", PID_HOST, rank, iv, t0))
    for dev in sorted(trace.devices):
        kern, mem = _device_lane_intervals(trace.devices[dev], (t0, t1))
        events.append(
            json.loads(_meta_line("thread_name", PID_DEVICE, f"dev {dev}", dev))
        )
        lane = (slice_events_loop("Kernel", "device", PID_DEVICE, dev, kern, t0)
                + slice_events_loop("Memory", "device", PID_DEVICE, dev, mem, t0))
        events.extend(lane[i] for i in _device_lane_order(kern, mem))
    parts = [json.dumps(ev, separators=(",", ":")) for ev in events]
    return _assemble(parts, trace.name)


def _pick_window_region(result: TalpResult) -> Optional[RegionResult]:
    g = result.regions.get(TalpMonitor.GLOBAL)
    if g is not None:
        return g
    if not result.regions:
        return None
    return max(result.regions.values(), key=lambda r: r.elapsed)


def export_result(
    result: TalpResult,
    timelines: Optional[Dict[int, DeviceTimeline]] = None,
    samples: Optional[Sequence[Tuple[float, TalpResult]]] = None,
) -> str:
    """Render a (single-rank or post-merge job-level)
    :class:`~repro.core.talp.TalpResult` as Chrome trace JSON.

    Host lanes are proportional slices from the per-rank state durations;
    device lanes are exact when raw ``timelines`` are attached (spool
    payloads carry them), proportional from the reduced device states
    otherwise. Regions become ``B``/``E`` markers anchored at the window
    start (a reduced result carries region durations, not timestamps —
    use :func:`export_monitor` for exact region windows).
    """
    with _ovh.section("export"):
        cov = getattr(result, "rank_coverage", None)
        other = (
            {"rank_coverage": cov.as_dict() if hasattr(cov, "as_dict")
             else cov}
            if cov is not None else None
        )
        g = _pick_window_region(result)
        if g is None:
            return _build(result.name, 0.0, {}, {}, {}, samples, other=other)
        device_lanes: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        if timelines:
            # Raw timelines live in the producing rank's clock domain;
            # re-anchor to the earliest record so lanes start at zero.
            starts = [tl.span()[0] for tl in timelines.values()
                      if tl.n_records]
            shift = min(starts) if starts else 0.0
            for dev, tl in timelines.items():
                kern, mem = _device_lane_intervals(tl)
                device_lanes[dev] = (kern - shift, mem - shift)
        else:
            for dev, st in g.device_states.items():
                device_lanes[dev] = _synthetic_device_intervals(st, 0.0)
        region_windows = {
            rname: np.array([[0.0, rr.elapsed]])
            for rname, rr in result.regions.items()
            if rr.elapsed > 0
        }
        return _build(
            result.name, 0.0, g.host_states, device_lanes,
            region_windows, samples, other=other,
        )


def export_monitor(
    mon: TalpMonitor,
    result: Optional[TalpResult] = None,
    samples: Optional[Sequence[Tuple[float, TalpResult]]] = None,
    step_series=None,
    anomalies=None,
) -> str:
    """Render a live (or finalized) monitor with *exact* region windows
    and device records — everything shares the monitor's clock domain, so
    region markers align with device slices. A ``step_series`` switches
    the counter tracks to step resolution (superseding ``samples``);
    ``anomalies`` adds watchdog instant markers."""
    with _ovh.section("export"):
        if result is None:
            result = mon.sample_result()
        g = _pick_window_region(result)
        region_windows = mon.region_windows()
        device_lanes = {
            dev: _device_lane_intervals(tl) for dev, tl in mon.devices.items()
        }
        anchors = [iv[0, 0] for iv in region_windows.values() if len(iv)]
        anchors += [tl.span()[0] for tl in mon.devices.values() if tl.n_records]
        t0 = min(anchors) if anchors else 0.0
        host_states = g.host_states if g is not None else {}
        return _build(
            result.name, t0, host_states, device_lanes,
            region_windows, samples,
            step_series=step_series, anomalies=anomalies,
        )


def export_job(
    job: TalpResult,
    rank_timelines: Dict[int, Dict[int, DeviceTimeline]],
) -> str:
    """Job-level trace from a merged result + the per-rank raw timeline
    attachments (``FileSpoolTransport.collect_timelines``). Local device
    ids are remapped to the same dense job-global ids the merge assigns
    ((rank-order, local-id) order), and each rank's records are
    re-anchored to its own first record — per-rank clocks do not share an
    epoch across nodes."""
    remapped: Dict[int, DeviceTimeline] = {}
    gid = 0
    for rank in sorted(rank_timelines):
        tls = rank_timelines[rank]
        starts = [tl.span()[0] for tl in tls.values() if tl.n_records]
        shift = min(starts) if starts else 0.0
        for dev in sorted(tls):
            tl = tls[dev]
            kern, mem = _device_lane_intervals(tl)
            shifted = DeviceTimeline(device=gid)
            if len(kern):
                shifted.ingest_arrays(DeviceActivity.KERNEL,
                                      kern[:, 0] - shift, kern[:, 1] - shift)
            if len(mem):
                shifted.ingest_arrays(DeviceActivity.MEMORY,
                                      mem[:, 0] - shift, mem[:, 1] - shift)
            remapped[gid] = shifted
            gid += 1
    return export_result(job, timelines=remapped or None)


# ---------------------------------------------------------------------------
# structural validator (tests + CI share it)
# ---------------------------------------------------------------------------
def validate_chrome_trace(
    text: str, overlap_tol_us: float = 2e-3
) -> Dict[str, object]:
    """Validate trace-event JSON structurally; raises ``ValueError`` on
    the first violation, returns a summary dict on success.

    Checks: valid JSON with a ``traceEvents`` list; every event has a
    known ``ph``; complete events carry numeric ``ts``/``dur``/``pid``/
    ``tid`` with ``dur >= 0``; per (pid, tid) lane the X events are
    monotonically ordered and non-overlapping (touching allowed; the
    default tolerance covers the exporter's ±0.5 ns ``ts`` quantization
    on both neighbors); ``B``/``E`` markers are balanced per lane and
    name with depth never going negative; counters carry numeric series
    args; instant events (``"i"``) carry a name, a numeric ``ts`` and a
    valid scope.
    """
    try:
        payload = json.loads(text)
    except json.JSONDecodeError as e:
        raise ValueError(f"trace is not valid JSON: {e}") from e
    events = payload.get("traceEvents")
    if not isinstance(events, list):
        raise ValueError("missing traceEvents list")
    lanes: Dict[Tuple[int, int], List[Tuple[float, float]]] = {}
    marker_depth: Dict[Tuple[int, int], int] = {}
    marker_last_ts: Dict[Tuple[int, int], float] = {}
    marker_open: Dict[Tuple[int, int, str], int] = {}
    counts = {"X": 0, "B": 0, "E": 0, "C": 0, "M": 0, "i": 0}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict) or "ph" not in ev:
            raise ValueError(f"event {i}: missing required field 'ph'")
        ph = ev["ph"]
        if ph not in counts:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        counts[ph] += 1
        if ph == "X":
            for f in ("ts", "dur", "pid", "tid"):
                if not isinstance(ev.get(f), (int, float)):
                    raise ValueError(
                        f"event {i}: complete event missing numeric {f!r}"
                    )
            if ev["dur"] < 0:
                raise ValueError(f"event {i}: negative dur {ev['dur']}")
            lanes.setdefault((ev["pid"], ev["tid"]), []).append(
                (float(ev["ts"]), float(ev["dur"]))
            )
        elif ph in ("B", "E"):
            for f in ("ts", "pid", "tid"):
                if not isinstance(ev.get(f), (int, float)):
                    raise ValueError(f"event {i}: marker missing numeric {f!r}")
            if "name" not in ev:
                raise ValueError(f"event {i}: marker missing 'name'")
            key = (ev["pid"], ev["tid"])
            ts = float(ev["ts"])
            if ts < marker_last_ts.get(key, -np.inf) - overlap_tol_us:
                raise ValueError(
                    f"event {i}: marker ts {ts} out of order on lane {key}"
                )
            marker_last_ts[key] = max(marker_last_ts.get(key, -np.inf), ts)
            d = marker_depth.get(key, 0) + (1 if ph == "B" else -1)
            if d < 0:
                raise ValueError(
                    f"event {i}: 'E' without matching 'B' on lane {key}"
                )
            marker_depth[key] = d
            nkey = (ev["pid"], ev["tid"], ev["name"])
            marker_open[nkey] = marker_open.get(nkey, 0) + (
                1 if ph == "B" else -1
            )
        elif ph == "i":
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(
                    f"event {i}: instant event missing numeric 'ts'"
                )
            if "name" not in ev:
                raise ValueError(f"event {i}: instant event missing 'name'")
            if ev.get("s", "t") not in ("t", "p", "g"):
                raise ValueError(
                    f"event {i}: instant event scope {ev.get('s')!r} invalid"
                )
        elif ph == "C":
            if not isinstance(ev.get("ts"), (int, float)):
                raise ValueError(f"event {i}: counter missing numeric 'ts'")
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                raise ValueError(f"event {i}: counter missing series args")
            for k, v in args.items():
                if not isinstance(v, (int, float)):
                    raise ValueError(
                        f"event {i}: counter series {k!r} non-numeric"
                    )
    for key, depth in marker_depth.items():
        if depth != 0:
            raise ValueError(f"unbalanced B/E markers on lane {key}")
    for (pid, tid, name), n in marker_open.items():
        if n != 0:
            raise ValueError(
                f"unbalanced B/E markers for region {name!r} on lane "
                f"({pid}, {tid})"
            )
    for key, slices in lanes.items():
        prev_end = -np.inf
        prev_ts = -np.inf
        for ts, dur in slices:
            if ts < prev_ts:
                raise ValueError(f"lane {key}: ts not monotonically ordered")
            if ts < prev_end - overlap_tol_us:
                raise ValueError(
                    f"lane {key}: overlapping slices (ts {ts} < previous "
                    f"end {prev_end})"
                )
            prev_ts = ts
            prev_end = max(prev_end, ts + dur)
    return {
        "n_events": len(events),
        "counts": counts,
        "lanes": {f"{pid}:{tid}": len(s) for (pid, tid), s in sorted(lanes.items())},
    }


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    import sys

    ap = argparse.ArgumentParser(
        description="Validate (or summarize) a Chrome trace-event JSON "
                    "file produced by the TALP trace exporter."
    )
    ap.add_argument("trace", help="trace JSON file to validate")
    ap.add_argument("--validate", action="store_true",
                    help="structural validation only (default behavior; "
                         "flag kept for explicit CI invocations)")
    args = ap.parse_args(argv)
    with open(args.trace) as f:
        text = f.read()
    try:
        summary = validate_chrome_trace(text)
    except ValueError as e:
        print(f"INVALID: {e}", file=sys.stderr)
        sys.exit(1)
    print(json.dumps({"valid": True, **summary}, indent=2))


if __name__ == "__main__":
    main()
