"""Observability subsystem: trace export, runtime metric stream, and
TALP self-overhead accounting.

Three pillars (see the module docstrings):

  * :mod:`.traceexport` — Chrome trace-event JSON (Perfetto /
    ``chrome://tracing``) rendered vectorized from the columnar buffers.
  * :mod:`.exporter` — :class:`TelemetryExporter`: ring-buffered
    ``sample_result()`` snapshots published as JSONL + Prometheus text.
  * :mod:`.overhead` — monotonic-clock accounting of the monitor's own
    hot paths, surfaced as the optional ``talp_overhead`` report branch.

Plus the step-resolution pair built on all three:

  * :mod:`.stepseries` — per-region-close metric capture into a bounded
    columnar ring (:class:`StepSeries` / :class:`StepSeriesRecorder`).
  * :mod:`.watchdog` — online :class:`EfficiencyWatchdog` with rolling
    EWMA/CUSUM baselines, hysteresis, and hierarchy-aware attribution.

Only :mod:`.overhead` is imported eagerly: it is dependency-free and the
core measurement modules (``states``/``talp``/``merge``) time their hot
paths against it, so it must never pull the exporters (which import
those same core modules) back in. Everything else loads lazily on first
attribute access.
"""

from __future__ import annotations

import importlib

from .overhead import OverheadAccumulator, current, install, section  # noqa: F401
from . import overhead  # noqa: F401

__all__ = [
    "OverheadAccumulator",
    "current",
    "install",
    "section",
    "overhead",
    "traceexport",
    "exporter",
    "stepseries",
    "watchdog",
    "TelemetryExporter",
    "TelemetrySnapshot",
    "StepSeries",
    "StepSeriesRecorder",
    "EfficiencyWatchdog",
    "AnomalyEvent",
    "validate_anomaly_events",
    "synthetic_drift_scenario",
    "export_trace",
    "export_trace_reference",
    "export_result",
    "export_monitor",
    "export_job",
    "validate_chrome_trace",
]

_LAZY = {
    "traceexport": (".traceexport", None),
    "exporter": (".exporter", None),
    "stepseries": (".stepseries", None),
    "watchdog": (".watchdog", None),
    "TelemetryExporter": (".exporter", "TelemetryExporter"),
    "TelemetrySnapshot": (".exporter", "TelemetrySnapshot"),
    "StepSeries": (".stepseries", "StepSeries"),
    "StepSeriesRecorder": (".stepseries", "StepSeriesRecorder"),
    "EfficiencyWatchdog": (".watchdog", "EfficiencyWatchdog"),
    "AnomalyEvent": (".watchdog", "AnomalyEvent"),
    "validate_anomaly_events": (".watchdog", "validate_anomaly_events"),
    "synthetic_drift_scenario": (".watchdog", "synthetic_drift_scenario"),
    "export_trace": (".traceexport", "export_trace"),
    "export_trace_reference": (".traceexport", "export_trace_reference"),
    "export_result": (".traceexport", "export_result"),
    "export_monitor": (".traceexport", "export_monitor"),
    "export_job": (".traceexport", "export_job"),
    "validate_chrome_trace": (".traceexport", "validate_chrome_trace"),
}


def __getattr__(name: str):
    try:
        modname, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}"
        ) from None
    mod = importlib.import_module(modname, __name__)
    return mod if attr is None else getattr(mod, attr)
