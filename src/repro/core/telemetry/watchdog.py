"""Online efficiency watchdog: rolling baselines, drift detection, and
hierarchy-aware anomaly attribution.

Job-specific monitoring systems (MPCDF's HPC monitor, arXiv:1909.11704)
turn raw telemetry into *automatic* reports because nobody stares at
dashboards for every job. This module does the same for the TALP metric
hierarchy at step resolution: an :class:`EfficiencyWatchdog` receives
the per-step metric rows produced by
:class:`~.stepseries.StepSeriesRecorder` and runs two detectors per
watched (region, metric):

  * an **EWMA baseline** (exponential mean + variance) with a z-score
    threshold — catches step-level spikes;
  * a two-sided **CUSUM** over the normalized residual — catches slow
    drifts that never individually exceed the z threshold.

Hysteresis suppresses flapping: once a detector fires, the baseline is
*frozen* and no further events are emitted for that (region, metric)
until the metric returns within ``z_clear`` for ``clear_after``
consecutive steps — a persistent regime shift therefore produces exactly
one event, not one per step.

Every event carries an **attribution path** computed from the
parent≡Π(children) structure of the hierarchy: the multiplicative
children of the degraded metric are ranked by how much they moved in log
space (``Δlog = log observed − log baseline``, the additive share of the
parent's relative change), and the path descends through the largest
mover at each level — so "parallel_efficiency dropped" arrives annotated
with "because load_balance dropped".

Events are structured dicts (see :func:`validate_anomaly_events` for the
schema) streamed to an optional JSONL sink, published by the
:class:`~.exporter.TelemetryExporter`, and rendered as instant markers
in the Chrome trace. :func:`synthetic_drift_scenario` (also the module
CLI) builds a deterministic two-device run with an injected mid-run load
imbalance — the end-to-end smoke test CI runs.
"""

from __future__ import annotations

import io
import json
import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..hierarchy import Hierarchy, MetricSpec
from .stepseries import DEFAULT_HIERARCHIES

__all__ = [
    "AnomalyEvent",
    "EfficiencyWatchdog",
    "validate_anomaly_events",
    "load_anomaly_jsonl",
    "synthetic_drift_scenario",
    "DEFAULT_WATCHED",
]

#: Metric columns watched when none are given: the two hierarchy roots
#: and the classic drift suspects underneath them.
DEFAULT_WATCHED: Tuple[str, ...] = (
    "host_parallel_efficiency",
    "host_device_offload_efficiency",
    "host_load_balance",
    "device_parallel_efficiency",
    "device_load_balance",
    "device_orchestration_efficiency",
)

_EVENT_KIND = "anomaly"
_DETECTORS = ("ewma", "cusum")
_DIRECTIONS = ("drop", "rise")


@dataclass
class _Baseline:
    """EWMA mean/variance of one (region, metric column)."""

    alpha: float
    mean: float = 0.0
    var: float = 0.0
    n: int = 0

    def update(self, x: float) -> None:
        if self.n == 0:
            self.mean = x
            self.var = 0.0
        else:
            d = x - self.mean
            self.mean += self.alpha * d
            # EW variance of the residual around the moving mean.
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d)
        self.n += 1

    def std(self, floor: float) -> float:
        return max(math.sqrt(max(self.var, 0.0)), floor)


@dataclass
class _Detector:
    """Per-(region, metric) detector state: CUSUM sums + hysteresis."""

    hi: float = 0.0
    lo: float = 0.0
    firing: bool = False
    clear_count: int = 0


@dataclass(frozen=True)
class AnomalyEvent:
    """One detected anomaly (``as_dict()`` is the JSONL record)."""

    step: int
    region: str
    hierarchy: str
    metric: str
    t: float
    observed: float
    baseline_mean: float
    baseline_std: float
    z: float
    cusum: float
    detector: str       # "ewma" | "cusum"
    direction: str      # "drop" | "rise"
    attribution: Tuple[Dict[str, float], ...] = ()

    @property
    def column(self) -> str:
        return f"{self.hierarchy}_{self.metric}"

    def as_dict(self) -> Dict[str, object]:
        return {
            "kind": _EVENT_KIND,
            "step": self.step,
            "region": self.region,
            "hierarchy": self.hierarchy,
            "metric": self.metric,
            "t": self.t,
            "observed": self.observed,
            "baseline_mean": self.baseline_mean,
            "baseline_std": self.baseline_std,
            "z": self.z,
            "cusum": self.cusum,
            "detector": self.detector,
            "direction": self.direction,
            "attribution": [dict(a) for a in self.attribution],
        }


class EfficiencyWatchdog:
    """Online anomaly detector over step-resolution hierarchy metrics.

    ``metrics`` selects the watched metric columns
    (``{hierarchy}_{key}`` names as produced by the step series); every
    *observed* column still gets a baseline so attribution can compare
    children against their own history. Tuning knobs:

      * ``alpha`` — EWMA weight of the newest sample;
      * ``z_fire`` / ``z_clear`` / ``clear_after`` — fire threshold and
        hysteresis clearing band (in sigmas / steps);
      * ``cusum_k`` / ``cusum_h`` — CUSUM slack and decision threshold
        (in sigmas);
      * ``min_sigma`` — variance floor, so a near-constant metric does
        not fire on numerical dust;
      * ``min_samples`` — warmup before any detection.

    ``jsonl`` (path or file-like) streams each event as one JSON line at
    emission time — crash-safe anomaly logging for the drivers'
    ``--talp-anomaly-log``.
    """

    def __init__(
        self,
        metrics: Sequence[str] = DEFAULT_WATCHED,
        hierarchies: Sequence[Hierarchy] = DEFAULT_HIERARCHIES,
        alpha: float = 0.2,
        z_fire: float = 6.0,
        z_clear: float = 2.0,
        clear_after: int = 3,
        cusum_k: float = 0.5,
        cusum_h: float = 10.0,
        min_sigma: float = 5e-3,
        min_samples: int = 8,
        jsonl=None,
    ):
        self.watched = tuple(metrics)
        self.hierarchies = tuple(hierarchies)
        self.alpha = float(alpha)
        self.z_fire = float(z_fire)
        self.z_clear = float(z_clear)
        self.clear_after = int(clear_after)
        self.cusum_k = float(cusum_k)
        self.cusum_h = float(cusum_h)
        self.min_sigma = float(min_sigma)
        self.min_samples = int(min_samples)
        self.events: List[AnomalyEvent] = []
        self._baselines: Dict[Tuple[str, str], _Baseline] = {}
        self._detectors: Dict[Tuple[str, str], _Detector] = {}
        # column -> (hierarchy, spec) for attribution walks.
        self._specs: Dict[str, Tuple[Hierarchy, MetricSpec]] = {}
        for h in self.hierarchies:
            for spec in h.walk():
                self._specs[f"{h.name}_{spec.key}"] = (h, spec)
        self._jsonl_path: Optional[str] = None
        self._jsonl = None
        if jsonl is not None:
            if isinstance(jsonl, (str, bytes)):
                self._jsonl_path = str(jsonl)
            else:
                self._jsonl = jsonl

    # -- lifecycle ---------------------------------------------------------
    def close(self) -> None:
        if self._jsonl is not None and self._jsonl_path is not None:
            self._jsonl.close()
            self._jsonl = None

    def _emit(self, ev: AnomalyEvent) -> None:
        self.events.append(ev)
        sink = self._jsonl
        if sink is None and self._jsonl_path is not None:
            sink = self._jsonl = io.open(self._jsonl_path, "w", encoding="utf-8")
        if sink is not None:
            sink.write(json.dumps(ev.as_dict()) + "\n")
            sink.flush()

    # -- observation -------------------------------------------------------
    def observe(
        self,
        region: str,
        step: int,
        t: float,
        values: Dict[str, float],
    ) -> List[AnomalyEvent]:
        """Feed one step row (metric column -> value); returns the events
        emitted for this row. NaN values are skipped (metric absent this
        step)."""
        out: List[AnomalyEvent] = []
        # Detection first, against baselines as of the *previous* steps;
        # then fold the row into the non-firing baselines.
        for col in self.watched:
            v = values.get(col)
            if v is None or math.isnan(v):
                continue
            ev = self._detect(region, step, t, col, float(v), values)
            if ev is not None:
                out.append(ev)
        for col, v in values.items():
            if v is None or math.isnan(v):
                continue
            key = (region, col)
            det = self._detectors.get(key)
            if det is not None and det.firing:
                continue  # baseline frozen while firing
            b = self._baselines.get(key)
            if b is None:
                b = self._baselines[key] = _Baseline(alpha=self.alpha)
            b.update(float(v))
        return out

    def _detect(
        self,
        region: str,
        step: int,
        t: float,
        col: str,
        x: float,
        row: Dict[str, float],
    ) -> Optional[AnomalyEvent]:
        key = (region, col)
        b = self._baselines.get(key)
        if b is None or b.n < self.min_samples:
            return None  # warmup
        det = self._detectors.get(key)
        if det is None:
            det = self._detectors[key] = _Detector()
        sigma = b.std(self.min_sigma)
        z = (x - b.mean) / sigma
        det.hi = max(0.0, det.hi + z - self.cusum_k)
        det.lo = max(0.0, det.lo - z - self.cusum_k)
        cusum = max(det.hi, det.lo)
        if det.firing:
            if abs(z) <= self.z_clear:
                det.clear_count += 1
                if det.clear_count >= self.clear_after:
                    det.firing = False
                    det.clear_count = 0
                    det.hi = det.lo = 0.0
            else:
                det.clear_count = 0
            return None
        ewma_fired = abs(z) >= self.z_fire
        cusum_fired = cusum >= self.cusum_h
        if not (ewma_fired or cusum_fired):
            return None
        det.firing = True
        det.clear_count = 0
        h, spec = self._specs.get(col, (None, None))
        ev = AnomalyEvent(
            step=step,
            region=region,
            hierarchy=h.name if h is not None else col.split("_", 1)[0],
            metric=spec.key if spec is not None else col.split("_", 1)[-1],
            t=t,
            observed=x,
            baseline_mean=b.mean,
            baseline_std=sigma,
            z=z,
            cusum=cusum,
            detector="ewma" if ewma_fired else "cusum",
            direction="drop" if z < 0 else "rise",
            attribution=tuple(self._attribute(region, col, row)),
        )
        self._emit(ev)
        return ev

    # -- attribution -------------------------------------------------------
    def _attribute(
        self, region: str, col: str, row: Dict[str, float]
    ) -> List[Dict[str, float]]:
        """Descend the multiplicative children of ``col``, one level per
        entry, following the largest |Δlog| mover — the additive share of
        the parent's relative change under parent = Π(children)."""
        path: List[Dict[str, float]] = []
        entry = self._specs.get(col)
        if entry is None:
            return path
        h, spec = entry
        tiny = 1e-12
        while True:
            movers: List[Tuple[float, Dict[str, float]]] = []
            for child in spec.children:
                if not child.multiplicative:
                    continue
                ccol = f"{h.name}_{child.key}"
                v = row.get(ccol)
                if v is None or math.isnan(v):
                    continue
                b = self._baselines.get((region, ccol))
                if b is None or b.n == 0:
                    continue
                dlog = math.log(max(float(v), tiny)) - math.log(
                    max(b.mean, tiny)
                )
                movers.append(
                    (
                        abs(dlog),
                        {
                            "metric": ccol,
                            "observed": float(v),
                            "baseline": b.mean,
                            "dlog": dlog,
                        },
                    )
                )
            if not movers:
                return path
            movers.sort(key=lambda m: m[0], reverse=True)
            top = movers[0]
            path.append(top[1])
            spec = next(
                c
                for c in spec.children
                if f"{h.name}_{c.key}" == top[1]["metric"]
            )

    # -- published state ---------------------------------------------------
    def firing(self) -> List[Dict[str, object]]:
        """Currently-firing (region, metric) pairs — the exporter's live
        watchdog state."""
        out: List[Dict[str, object]] = []
        for (region, col), det in sorted(self._detectors.items()):
            if det.firing:
                out.append({"region": region, "metric": col})
        return out

    def summary(self) -> Dict[str, object]:
        return {
            "watched": list(self.watched),
            "n_events": len(self.events),
            "firing": self.firing(),
        }


# ---------------------------------------------------------------------------
# the shared anomaly-event schema checker
# ---------------------------------------------------------------------------
_REQUIRED_NUMERIC = (
    "t", "observed", "baseline_mean", "baseline_std", "z", "cusum",
)


def validate_anomaly_events(events: Sequence[Dict[str, object]]) -> int:
    """Structural check of anomaly-event dicts (the JSONL schema used by
    tests, CI, and any downstream consumer). Raises ``ValueError`` on the
    first malformed event; returns the number of validated events."""

    def fail(i: int, msg: str):
        raise ValueError(f"anomaly event {i}: {msg}")

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            fail(i, f"not a dict: {type(ev).__name__}")
        if ev.get("kind") != _EVENT_KIND:
            fail(i, f"kind must be {_EVENT_KIND!r}, got {ev.get('kind')!r}")
        if not isinstance(ev.get("step"), int) or isinstance(ev.get("step"), bool):
            fail(i, f"step must be an int, got {ev.get('step')!r}")
        for k in ("region", "hierarchy", "metric"):
            v = ev.get(k)
            if not isinstance(v, str) or not v:
                fail(i, f"{k} must be a non-empty string, got {v!r}")
        for k in _REQUIRED_NUMERIC:
            v = ev.get(k)
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                fail(i, f"{k} must be a number, got {v!r}")
            if not math.isfinite(float(v)):
                fail(i, f"{k} must be finite, got {v!r}")
        if float(ev["baseline_std"]) < 0 or float(ev["cusum"]) < 0:
            fail(i, "baseline_std and cusum must be >= 0")
        if ev.get("detector") not in _DETECTORS:
            fail(i, f"detector must be one of {_DETECTORS}, got {ev.get('detector')!r}")
        if ev.get("direction") not in _DIRECTIONS:
            fail(i, f"direction must be one of {_DIRECTIONS}, got {ev.get('direction')!r}")
        attr = ev.get("attribution")
        if not isinstance(attr, list):
            fail(i, f"attribution must be a list, got {type(attr).__name__}")
        for j, a in enumerate(attr):
            if not isinstance(a, dict):
                fail(i, f"attribution[{j}] not a dict")
            if not isinstance(a.get("metric"), str) or not a.get("metric"):
                fail(i, f"attribution[{j}].metric must be a non-empty string")
            for k in ("observed", "baseline", "dlog"):
                v = a.get(k)
                if isinstance(v, bool) or not isinstance(v, (int, float)):
                    fail(i, f"attribution[{j}].{k} must be a number, got {v!r}")
    return len(events)


def load_anomaly_jsonl(path: str) -> List[Dict[str, object]]:
    """Read an anomaly JSONL file back into event dicts."""
    out: List[Dict[str, object]] = []
    with io.open(path, "r", encoding="utf-8") as fh:
        for line in fh:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


# ---------------------------------------------------------------------------
# deterministic end-to-end scenario (tests + CI smoke)
# ---------------------------------------------------------------------------
class _DemoClock:
    """Deterministic monotonically advancing clock for the scenario."""

    def __init__(self, t: float = 0.0):
        self.t = t

    def now(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def synthetic_drift_scenario(
    steps: int = 60,
    inject: bool = True,
    seed: int = 0,
    capacity: Optional[int] = None,
    anomaly_log=None,
    region: str = "step",
):
    """Two-device synthetic run with an optional load-imbalance injection
    at the midpoint: device 1's kernels shrink to ~40% of device 0's, so
    the device ``load_balance`` (and with it ``parallel_efficiency``)
    drops sharply while ``orchestration_efficiency`` stays put — the
    watchdog should fire on the device metrics with an attribution path
    ending at ``device_load_balance``, and stay silent when
    ``inject=False``.

    Returns a dict with ``monitor``, ``recorder`` (its ``.series`` is the
    step series), ``watchdog``, ``result`` (finalized TalpResult) and
    ``inject_step`` (the first degraded step index, or None).
    """
    from ..states import DeviceActivity
    from ..talp import TalpMonitor
    from .stepseries import StepSeriesRecorder

    rng = np.random.default_rng(seed)
    clk = _DemoClock()
    mon = TalpMonitor(
        "drift-demo", clock=clk.now, auto_start=True, overhead_report=True
    )
    wd = EfficiencyWatchdog(
        metrics=(
            "device_parallel_efficiency",
            "device_load_balance",
            "device_orchestration_efficiency",
            "host_parallel_efficiency",
            "host_device_offload_efficiency",
        ),
        jsonl=anomaly_log,
    )
    rec = StepSeriesRecorder(
        mon, capacity=capacity or max(steps + 8, 16), watchdog=wd
    )
    inject_step = steps // 2 if inject else None
    base = 0.008  # nominal per-step kernel busy seconds
    for i in range(steps):
        with mon.region(region):
            t0 = clk.now()
            k0 = base * (1.0 + 0.005 * float(rng.standard_normal()))
            k1 = base * (1.0 + 0.005 * float(rng.standard_normal()))
            if inject_step is not None and i >= inject_step:
                k1 *= 0.4  # device 1 starves: load imbalance appears
            mon.add_device_record(0, DeviceActivity.KERNEL, t0, t0 + k0)
            mon.add_device_record(1, DeviceActivity.KERNEL, t0, t0 + k1)
            with mon.offload():
                clk.advance(max(k0, k1))  # host blocked on the sync
            clk.advance(0.001)  # useful host tail
    result = mon.finalize()
    return {
        "monitor": mon,
        "recorder": rec,
        "watchdog": wd,
        "result": result,
        "inject_step": inject_step,
    }


# ---------------------------------------------------------------------------
# CLI: run the scenario / validate an anomaly log
# ---------------------------------------------------------------------------
def main(argv: Optional[Sequence[str]] = None) -> int:
    import argparse

    p = argparse.ArgumentParser(
        prog="python -m repro.core.telemetry.watchdog",
        description=(
            "Run the synthetic drift scenario through the step-series "
            "recorder + efficiency watchdog, or validate an anomaly JSONL."
        ),
    )
    p.add_argument("--steps", type=int, default=60, help="scenario steps")
    p.add_argument(
        "--steady", action="store_true",
        help="no injection (expect zero anomalies)",
    )
    p.add_argument("--seed", type=int, default=0, help="noise seed")
    p.add_argument(
        "--anomaly-log", default=None, metavar="PATH",
        help="stream anomaly events to this JSONL file",
    )
    p.add_argument(
        "--trace-out", default=None, metavar="PATH",
        help="write a per-step Chrome trace (with anomaly markers)",
    )
    p.add_argument(
        "--step-table", action="store_true",
        help="print the per-step metric table",
    )
    p.add_argument(
        "--expect-anomaly", action="store_true",
        help="exit 1 unless >= 1 anomaly was detected",
    )
    p.add_argument(
        "--expect-clean", action="store_true",
        help="exit 1 if any anomaly was detected",
    )
    p.add_argument(
        "--validate", default=None, metavar="PATH",
        help="validate an anomaly JSONL file and exit",
    )
    args = p.parse_args(argv)

    if args.validate is not None:
        try:
            n = validate_anomaly_events(load_anomaly_jsonl(args.validate))
        except (ValueError, OSError, json.JSONDecodeError) as e:
            print(f"INVALID: {e}")
            return 1
        print(f"OK: {n} anomaly events valid")
        return 0

    sc = synthetic_drift_scenario(
        steps=args.steps,
        inject=not args.steady,
        seed=args.seed,
        anomaly_log=args.anomaly_log,
    )
    wd: EfficiencyWatchdog = sc["watchdog"]
    wd.close()
    validate_anomaly_events([e.as_dict() for e in wd.events])
    if args.step_table:
        print(sc["recorder"].series.as_table())
    for ev in wd.events:
        attr = " -> ".join(a["metric"] for a in ev.attribution)
        print(
            f"anomaly step={ev.step} region={ev.region} "
            f"metric={ev.hierarchy}:{ev.metric} {ev.direction} "
            f"z={ev.z:+.1f} observed={ev.observed:.4f} "
            f"baseline={ev.baseline_mean:.4f}"
            + (f" attribution: {attr}" if attr else "")
        )
    print(
        f"{len(wd.events)} anomaly events over {args.steps} steps "
        f"(inject={'no' if args.steady else 'yes'})"
    )
    if args.trace_out:
        from .traceexport import export_monitor

        trace = export_monitor(
            sc["monitor"],
            result=sc["result"],
            step_series=sc["recorder"].series,
            anomalies=wd.events,
        )
        with io.open(args.trace_out, "w", encoding="utf-8") as fh:
            fh.write(trace)
        print(f"trace written to {args.trace_out}")
    if args.expect_anomaly and not wd.events:
        print("FAIL: expected >= 1 anomaly, got none")
        return 1
    if args.expect_clean and wd.events:
        print(f"FAIL: expected zero anomalies, got {len(wd.events)}")
        return 1
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
