"""Synthetic trace construction — the PILS substrate.

PILS (paper §5.1) is a microbenchmark that *constructs controlled
execution patterns* (imbalance, offload, transfers, overlap) to validate
the metrics. This builder is the pattern-construction engine: cursors
advance per rank and per device, states are appended sequentially, and
``barrier()`` models an MPI blocking synchronization (laggard ranks wait
in MPI until the slowest arrives) — exactly how the paper's traces are
shaped (red MPI regions while waiting for rank 0, etc.).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from ..recordio import as_record_columns
from ..states import DeviceActivity, DeviceRecord, HostState, Trace
from .base import register_backend

__all__ = ["SyntheticTraceBuilder", "SyntheticBackend"]


@dataclass
class _RankCursor:
    builder: "SyntheticTraceBuilder"
    rank: int
    t: float = 0.0

    def _host(self, state: HostState, dur: float) -> "_RankCursor":
        if dur < 0:
            raise ValueError("negative duration")
        self.builder.trace.host(self.rank).add(state, dur)
        self.t += dur
        return self

    def useful(self, dur: float) -> "_RankCursor":
        return self._host(HostState.USEFUL, dur)

    def mpi(self, dur: float) -> "_RankCursor":
        return self._host(HostState.MPI, dur)

    def offload(self, dur: float) -> "_RankCursor":
        """Host blocked in device runtime calls for `dur` seconds."""
        return self._host(HostState.OFFLOAD, dur)

    # -- combined host+device idioms used by PILS patterns -------------
    def offload_kernel(self, dur: float, device: Optional[int] = None,
                       stream: int = 0) -> "_RankCursor":
        """Synchronous offload: host blocked while its GPU runs a kernel."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.KERNEL, self.t, self.t + dur, stream=stream
        )
        return self._host(HostState.OFFLOAD, dur)

    def offload_memory(self, dur: float, device: Optional[int] = None,
                       stream: int = 0) -> "_RankCursor":
        """Synchronous transfer: host blocked while data moves."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.MEMORY, self.t, self.t + dur, stream=stream
        )
        return self._host(HostState.OFFLOAD, dur)

    def async_kernel(self, dur: float, device: Optional[int] = None,
                     launch: float = 0.0, stream: int = 0) -> "_RankCursor":
        """Asynchronous launch: kernel starts now; host continues (use
        case 7's overlapped execution). ``launch`` charges a small host
        offload cost for the launch call itself."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.KERNEL, self.t + launch, self.t + launch + dur,
            stream=stream,
        )
        if launch > 0:
            self._host(HostState.OFFLOAD, launch)
        return self


@dataclass
class SyntheticTraceBuilder:
    nranks: int = 2
    ndevices: Optional[int] = None
    name: str = "synthetic"
    trace: Trace = field(init=False)
    _cursors: Dict[int, _RankCursor] = field(init=False, default_factory=dict)

    def __post_init__(self):
        self.trace = Trace(name=self.name)
        if self.ndevices is None:
            self.ndevices = self.nranks
        for r in range(self.nranks):
            self.trace.host(r)
        for d in range(self.ndevices):
            self.trace.device(d)

    def rank(self, r: int) -> _RankCursor:
        if r not in self._cursors:
            self._cursors[r] = _RankCursor(self, r)
        return self._cursors[r]

    def barrier(self) -> "SyntheticTraceBuilder":
        """MPI blocking synchronization: every rank waits (MPI state)
        until the slowest cursor arrives."""
        tmax = max((c.t for c in self._cursors.values()), default=0.0)
        for r in range(self.nranks):
            c = self.rank(r)
            if c.t < tmax:
                c.mpi(tmax - c.t)
        return self

    def device_kernel(self, dev: int, start: float, dur: float,
                      stream: int = 0) -> "SyntheticTraceBuilder":
        self.trace.device(dev).add(DeviceActivity.KERNEL, start, start + dur,
                                   stream=stream)
        return self

    def device_memory(self, dev: int, start: float, dur: float,
                      stream: int = 0) -> "SyntheticTraceBuilder":
        self.trace.device(dev).add(DeviceActivity.MEMORY, start, start + dur,
                                   stream=stream)
        return self

    def build(self, window: Optional[Tuple[float, float]] = None) -> Trace:
        if window is None:
            t_host = max((c.t for c in self._cursors.values()), default=0.0)
            t_dev = max(
                (tl.span()[1] for tl in self.trace.devices.values()),
                default=0.0,
            )
            window = (0.0, max(t_host, t_dev))
        self.trace.window = window
        return self.trace


@register_backend("synthetic")
class SyntheticBackend:
    """ActivityBackend that replays pre-built activity (testing).

    Columnar inside: events are kept as per-device ``(kind_code, start,
    end, stream)`` column lists — no ``DeviceRecord`` objects are
    materialized unless a consumer insists on the legacy ``flush()``
    path. ``push_arrays`` accepts whole column batches;
    ``flush_arrays`` drains them batch-for-batch.
    """

    def __init__(self, records: Optional[Iterable[Tuple[int, DeviceRecord]]] = None):
        # dev -> list of (kinds, starts, ends, streams) column batches
        self._batches: Dict[int, List[Tuple[np.ndarray, np.ndarray,
                                            np.ndarray, np.ndarray]]] = {}
        self.started = False
        for dev, rec in records or []:
            self.push(dev, rec)

    def start(self) -> None:
        self.started = True

    def stop(self) -> None:
        self.started = False

    def push(self, dev: int, record: DeviceRecord) -> None:
        """Legacy single-record entry point (wraps a one-row batch)."""
        self.push_arrays(
            dev,
            np.array([record.kind.code], dtype=np.uint8),
            np.array([record.start]),
            np.array([record.end]),
            np.array([record.stream], dtype=np.uint32),
        )

    def push_arrays(self, dev: int, kinds, starts, ends, streams=None) -> None:
        """Queue one whole activity buffer for a device, as columns."""
        cols = as_record_columns(kinds, starts, ends, streams)
        self._batches.setdefault(dev, []).append(cols)

    def flush_arrays(self):
        """Drain queued per-device column batches (the zero-object path)."""
        out = []
        for dev in sorted(self._batches):
            out.extend((dev, *cols) for cols in self._batches[dev])
        self._batches = {}
        return out

    def flush(self):
        """Legacy object path: materialize ``DeviceRecord`` per event."""
        out = []
        for dev, kinds, starts, ends, streams in self.flush_arrays():
            out.extend(
                (dev, DeviceRecord(DeviceActivity.from_code(k), float(s),
                                   float(e), int(st)))
                for k, s, e, st in zip(kinds, starts, ends, streams)
            )
        return out
