"""Synthetic trace construction — the PILS substrate.

PILS (paper §5.1) is a microbenchmark that *constructs controlled
execution patterns* (imbalance, offload, transfers, overlap) to validate
the metrics. This builder is the pattern-construction engine: cursors
advance per rank and per device, states are appended sequentially, and
``barrier()`` models an MPI blocking synchronization (laggard ranks wait
in MPI until the slowest arrives) — exactly how the paper's traces are
shaped (red MPI regions while waiting for rank 0, etc.).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

from ..states import DeviceActivity, DeviceRecord, HostState, Trace
from .base import register_backend

__all__ = ["SyntheticTraceBuilder", "SyntheticBackend"]


@dataclass
class _RankCursor:
    builder: "SyntheticTraceBuilder"
    rank: int
    t: float = 0.0

    def _host(self, state: HostState, dur: float) -> "_RankCursor":
        if dur < 0:
            raise ValueError("negative duration")
        self.builder.trace.host(self.rank).add(state, dur)
        self.t += dur
        return self

    def useful(self, dur: float) -> "_RankCursor":
        return self._host(HostState.USEFUL, dur)

    def mpi(self, dur: float) -> "_RankCursor":
        return self._host(HostState.MPI, dur)

    def offload(self, dur: float) -> "_RankCursor":
        """Host blocked in device runtime calls for `dur` seconds."""
        return self._host(HostState.OFFLOAD, dur)

    # -- combined host+device idioms used by PILS patterns -------------
    def offload_kernel(self, dur: float, device: Optional[int] = None,
                       stream: int = 0) -> "_RankCursor":
        """Synchronous offload: host blocked while its GPU runs a kernel."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.KERNEL, self.t, self.t + dur, stream=stream
        )
        return self._host(HostState.OFFLOAD, dur)

    def offload_memory(self, dur: float, device: Optional[int] = None,
                       stream: int = 0) -> "_RankCursor":
        """Synchronous transfer: host blocked while data moves."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.MEMORY, self.t, self.t + dur, stream=stream
        )
        return self._host(HostState.OFFLOAD, dur)

    def async_kernel(self, dur: float, device: Optional[int] = None,
                     launch: float = 0.0, stream: int = 0) -> "_RankCursor":
        """Asynchronous launch: kernel starts now; host continues (use
        case 7's overlapped execution). ``launch`` charges a small host
        offload cost for the launch call itself."""
        dev = self.rank if device is None else device
        self.builder.trace.device(dev).add(
            DeviceActivity.KERNEL, self.t + launch, self.t + launch + dur,
            stream=stream,
        )
        if launch > 0:
            self._host(HostState.OFFLOAD, launch)
        return self


@dataclass
class SyntheticTraceBuilder:
    nranks: int = 2
    ndevices: Optional[int] = None
    name: str = "synthetic"
    trace: Trace = field(init=False)
    _cursors: Dict[int, _RankCursor] = field(init=False, default_factory=dict)

    def __post_init__(self):
        self.trace = Trace(name=self.name)
        if self.ndevices is None:
            self.ndevices = self.nranks
        for r in range(self.nranks):
            self.trace.host(r)
        for d in range(self.ndevices):
            self.trace.device(d)

    def rank(self, r: int) -> _RankCursor:
        if r not in self._cursors:
            self._cursors[r] = _RankCursor(self, r)
        return self._cursors[r]

    def barrier(self) -> "SyntheticTraceBuilder":
        """MPI blocking synchronization: every rank waits (MPI state)
        until the slowest cursor arrives."""
        tmax = max((c.t for c in self._cursors.values()), default=0.0)
        for r in range(self.nranks):
            c = self.rank(r)
            if c.t < tmax:
                c.mpi(tmax - c.t)
        return self

    def device_kernel(self, dev: int, start: float, dur: float,
                      stream: int = 0) -> "SyntheticTraceBuilder":
        self.trace.device(dev).add(DeviceActivity.KERNEL, start, start + dur,
                                   stream=stream)
        return self

    def device_memory(self, dev: int, start: float, dur: float,
                      stream: int = 0) -> "SyntheticTraceBuilder":
        self.trace.device(dev).add(DeviceActivity.MEMORY, start, start + dur,
                                   stream=stream)
        return self

    def build(self, window: Optional[Tuple[float, float]] = None) -> Trace:
        if window is None:
            t_host = max((c.t for c in self._cursors.values()), default=0.0)
            t_dev = max(
                (tl.span()[1] for tl in self.trace.devices.values()),
                default=0.0,
            )
            window = (0.0, max(t_host, t_dev))
        self.trace.window = window
        return self.trace


@register_backend("synthetic")
class SyntheticBackend:
    """ActivityBackend that replays a pre-built record list (testing)."""

    def __init__(self, records: Optional[Iterable[Tuple[int, DeviceRecord]]] = None):
        self._records: List[Tuple[int, DeviceRecord]] = list(records or [])
        self.started = False

    def start(self) -> None:
        self.started = True

    def stop(self) -> None:
        self.started = False

    def push(self, dev: int, record: DeviceRecord) -> None:
        self._records.append((dev, record))

    def flush(self):
        out, self._records = self._records, []
        return out
