"""Live runtime backend — the JAX analogue of the CUPTI plugin.

The paper's plugins have two paths: synchronous host-API callbacks and
asynchronous device activity records. On a JAX stack:

  * host path: timing scopes around dispatch / ``block_until_ready`` /
    ``device_put`` (JAX has no user-visible per-kernel callback API, but
    dispatch boundaries are exactly the host-blocked-in-runtime windows
    the paper measures);
  * device path: execution windows of dispatched computations, buffered
    as activity records and delivered on ``flush()``. JAX dispatch is
    asynchronous (like CUDA streams), so ``launch()`` + ``wait()``
    reproduces the overlap semantics of use case 7: the device record
    spans launch→ready while the host is only charged for the blocked
    portion.

This is a proof-of-concept on CPU (the container's "device" is the host
CPU), faithful in mechanics; on a real TPU the same scopes wrap the same
dispatch boundaries.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, List, Optional, Tuple

import numpy as np

from ..states import DeviceActivity, DeviceRecord
from ..telemetry import overhead as _ovh
from .base import register_backend

__all__ = ["RuntimeBackend", "AsyncHandle"]


class _DeviceColumns:
    """Per-device scalar-append column buffer (kind/start/end/stream).

    Append is O(1) Python-list work — no object per record; drain
    converts to NumPy columns in one shot.
    """

    __slots__ = ("kinds", "starts", "ends", "streams")

    def __init__(self):
        self.kinds: List[int] = []
        self.starts: List[float] = []
        self.ends: List[float] = []
        self.streams: List[int] = []

    def append(self, kind: int, start: float, end: float, stream: int) -> None:
        self.kinds.append(kind)
        self.starts.append(start)
        self.ends.append(end)
        self.streams.append(stream)

    def drain(self):
        cols = (
            np.asarray(self.kinds, dtype=np.uint8),
            np.asarray(self.starts, dtype=np.float64),
            np.asarray(self.ends, dtype=np.float64),
            np.asarray(self.streams, dtype=np.uint32),
        )
        self.kinds, self.starts, self.ends, self.streams = [], [], [], []
        return cols


@dataclass
class AsyncHandle:
    """Tracks one asynchronous dispatch (≙ work on a CUDA stream)."""

    out: Any
    launch_t: float
    device: int
    name: str
    stream: int = 0
    done_t: Optional[float] = None


@register_backend("runtime")
class RuntimeBackend:
    """Collects device activity records from live JAX execution."""

    def __init__(self, clock: Callable[[], float] = time.perf_counter):
        self.clock = clock
        self._columns: dict = {}  # dev -> _DeviceColumns
        self._pending: List[AsyncHandle] = []
        self.enabled = False

    # -- plugin lifecycle ------------------------------------------------
    def start(self) -> None:
        self.enabled = True

    def stop(self) -> None:
        # Drain pending asynchronous work before disabling.
        for h in list(self._pending):
            self.wait(h)
        self.enabled = False

    def _record(self, dev: int, kind: DeviceActivity, start: float,
                end: float, stream: int = 0) -> None:
        cols = self._columns.get(dev)
        if cols is None:
            cols = self._columns[dev] = _DeviceColumns()
        cols.append(kind.code, start, end, stream)

    def flush_arrays(self):
        """Drain buffered activity as per-device column batches."""
        with _ovh.section("flush"):
            out = [
                (dev, *self._columns[dev].drain())
                for dev in sorted(self._columns)
            ]
            return out

    def flush(self):
        """Legacy object path: materialize ``DeviceRecord`` per event."""
        out = []
        for dev, kinds, starts, ends, streams in self.flush_arrays():
            out.extend(
                (dev, DeviceRecord(DeviceActivity.from_code(k), float(s),
                                   float(e), int(st)))
                for k, s, e, st in zip(kinds, starts, ends, streams)
            )
        return out

    # -- device activity (async path) ------------------------------------
    def launch(self, fn: Callable, *args, device: int = 0, name: str = "",
               stream: int = 0, **kwargs) -> AsyncHandle:
        """Dispatch without blocking; the device record is completed at
        ``wait()``. Host cost of the launch call itself is whatever the
        caller's scope charges (typically microseconds)."""
        t0 = self.clock()
        out = fn(*args, **kwargs)
        h = AsyncHandle(out=out, launch_t=t0, device=device,
                        name=name or getattr(fn, "__name__", "fn"), stream=stream)
        self._pending.append(h)
        return h

    def wait(self, handle: AsyncHandle) -> Any:
        """Block until ready; emit the kernel activity record."""
        import jax

        out = jax.block_until_ready(handle.out)
        handle.done_t = self.clock()
        if self.enabled:
            self._record(
                handle.device, DeviceActivity.KERNEL,
                handle.launch_t, handle.done_t, handle.stream,
            )
        if handle in self._pending:
            self._pending.remove(handle)
        return out

    # -- synchronous helpers ----------------------------------------------
    def run_sync(self, fn: Callable, *args, device: int = 0, name: str = "",
                 **kwargs) -> Any:
        h = self.launch(fn, *args, device=device, name=name, **kwargs)
        return self.wait(h)

    def record_transfer(self, fn: Callable, *args, device: int = 0,
                        name: str = "transfer", **kwargs) -> Any:
        """Time a host↔device data movement as a MEMORY record."""
        import jax

        t0 = self.clock()
        out = jax.block_until_ready(fn(*args, **kwargs))
        t1 = self.clock()
        if self.enabled:
            self._record(device, DeviceActivity.MEMORY, t0, t1)
        return out
