"""Analytical backend — device activity *predicted* from a compiled XLA program.

This is the TPU-native adaptation of the paper's activity-record path
(DESIGN.md §2): on a single-tenant accelerator running an AOT-compiled
SPMD program, the device timeline is statically predictable from the
compiled artifact. We derive per-step device state durations from the
three roofline terms:

    kernel time   = max(compute term, HBM term)   (compute/HBM overlap
                    inside fused kernels — the paper counts overlap as
                    computation)
    memory time   = (1 - overlap) × collective term  (ICI transfers that
                    are not hidden behind kernels)
    idle time     = host-side orchestration gap per step

and synthesize a ``Trace`` on which the *exact same* eqs. (9)–(12)
pipeline runs. This also supplies the paper's future-work branch,
**Device Computational Efficiency**, as useful-model-FLOPs over peak
during kernel time (beyond-paper extension).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from ..analysis import TraceAnalysis, analyze_trace
from ..states import DeviceActivity, Trace

__all__ = ["HardwareSpec", "TPU_V5E", "StepModel", "AnalyticalBackend",
           "trace_from_step_model"]


@dataclass(frozen=True)
class HardwareSpec:
    """Per-chip hardware constants (defaults: TPU v5e, task spec)."""

    name: str = "tpu_v5e"
    peak_flops: float = 197e12      # bf16 FLOP/s per chip
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link


TPU_V5E = HardwareSpec()


@dataclass(frozen=True)
class StepModel:
    """Roofline-derived per-step, per-device execution model.

    All byte/FLOP counts are **per device** (the compiled SPMD program is
    the per-device program).
    """

    flops: float                    # HLO FLOPs per device per step
    hbm_bytes: float                # HLO bytes accessed per device per step
    collective_bytes: float         # collective operand bytes per device per step
    model_flops: float = 0.0        # useful model FLOPs per device per step
    hw: HardwareSpec = TPU_V5E
    collective_overlap: float = 0.0  # fraction of collective time hidden
    host_gap_s: float = 0.0         # per-step orchestration gap (host-induced)

    @property
    def compute_s(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def hbm_s(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def kernel_s(self) -> float:
        return max(self.compute_s, self.hbm_s)

    @property
    def memory_s(self) -> float:
        return (1.0 - self.collective_overlap) * self.collective_s

    @property
    def step_s(self) -> float:
        return self.kernel_s + self.memory_s + self.host_gap_s

    @property
    def computational_efficiency(self) -> Optional[float]:
        """Beyond-paper Device Computational Efficiency branch."""
        if self.model_flops <= 0 or self.kernel_s <= 0:
            return None
        return (self.model_flops / self.hw.peak_flops) / self.kernel_s


def trace_from_step_model(
    models: Sequence[StepModel],
    steps: int = 1,
    host_useful_s: float = 0.0,
) -> Trace:
    """Synthesize a job trace: one StepModel per device, repeated ``steps``
    times. Device imbalance is expressed by passing per-device models with
    different FLOP counts.

    Device activity is generated **columnar**: per device, the kernel and
    memory records of all steps are computed as whole start/end columns
    (one ``arange`` per device) and delivered through
    :meth:`~repro.core.states.DeviceTimeline.ingest_arrays` — no
    per-step Python loop, no ``DeviceRecord`` objects."""
    trace = Trace(name="analytical")
    step_busy = max(m.kernel_s + m.memory_s for m in models)
    step_gap = max(m.host_gap_s for m in models)
    period = host_useful_s + step_busy + step_gap
    # step s starts its device work at host_useful_s + s*period
    t0s = host_useful_s + period * np.arange(steps, dtype=np.float64)
    for d, m in enumerate(models):
        tl = trace.device(d)
        if m.kernel_s > 0:
            tl.ingest_arrays(DeviceActivity.KERNEL, t0s, t0s + m.kernel_s)
        if m.memory_s > 0:
            tl.ingest_arrays(
                DeviceActivity.MEMORY,
                t0s + m.kernel_s,
                t0s + m.kernel_s + m.memory_s,
            )
    t = steps * period
    # Host: one rank per device group; host is Useful for host_useful_s,
    # Offload while blocked on its own device pipeline (+ gap), and in
    # MPI while waiting for slower peers.
    for d, m in enumerate(models):
        busy_d = m.kernel_s + m.memory_s
        h = trace.host(d)
        h.useful = steps * host_useful_s
        h.offload = steps * (busy_d + step_gap)
        h.mpi = steps * max(0.0, step_busy - busy_d)
    trace.window = (0.0, t)
    return trace


class AnalyticalBackend:
    """Wraps StepModels into the standard analysis pipeline."""

    def __init__(self, models: Sequence[StepModel], steps: int = 1,
                 host_useful_s: float = 0.0):
        self.models = list(models)
        self.steps = steps
        self.host_useful_s = host_useful_s

    def analyze(self) -> TraceAnalysis:
        trace = trace_from_step_model(self.models, self.steps, self.host_useful_s)
        ce = self.models[0].computational_efficiency if self.models else None
        return analyze_trace(trace, computational_efficiency=ce)
