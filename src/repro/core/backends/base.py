"""Activity-backend plugin protocol (≙ the paper's CUPTI / rocprofiler plugins).

Each backend implements two complementary paths, mirroring §4.2:

  (i)  synchronous monitoring of host API calls — in TALP-JAX this is
       the monitor's ``offload()`` / ``instrument()`` scopes, which the
       backend may hook;
  (ii) asynchronous collection of device activity records, delivered in
       batches via ``flush()`` and post-processed uniformly by the core
       (flatten kernels → subtract overlap from memory → classify idle).

Backends register by name so a deployment enables whichever matches the
runtime environment (the paper: CUPTI plugin if CUDA, rocprofiler if HIP,
OpenACC hooks if the OpenACC runtime is detected).
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Protocol, Tuple, runtime_checkable

from ..states import DeviceRecord

__all__ = ["ActivityBackend", "register_backend", "get_backend", "available_backends"]


@runtime_checkable
class ActivityBackend(Protocol):
    """Protocol every plugin implements."""

    def start(self) -> None:
        """Enable collection (≙ cuptiActivityEnable / rocprofiler filters)."""
        ...

    def stop(self) -> None:
        """Disable collection and release resources."""
        ...

    def flush(self) -> Iterable[Tuple[int, DeviceRecord]]:
        """Drain buffered (device, record) pairs (≙ activity-buffer flush)."""
        ...


_REGISTRY: Dict[str, Callable[..., ActivityBackend]] = {}


def register_backend(name: str):
    def deco(factory: Callable[..., ActivityBackend]):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_backend(name: str, **kwargs) -> ActivityBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available_backends() -> List[str]:
    return sorted(_REGISTRY)
