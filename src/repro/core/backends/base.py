"""Activity-backend plugin protocol (≙ the paper's CUPTI / rocprofiler plugins).

Each backend implements two complementary paths, mirroring §4.2:

  (i)  synchronous monitoring of host API calls — in TALP-JAX this is
       the monitor's ``offload()`` / ``instrument()`` scopes, which the
       backend may hook;
  (ii) asynchronous collection of device activity records, delivered in
       batches and post-processed uniformly by the core (flatten kernels
       → subtract overlap from memory → classify idle).

Delivery has two shapes. The legacy ``flush()`` yields one ``(device,
DeviceRecord)`` pair per event — simple, but it materializes a Python
object per activity record. The **batch path**, ``flush_arrays()``,
yields whole activity buffers as columns ``(device, kinds, starts,
ends, streams)`` that feed straight into
:meth:`~repro.core.states.DeviceTimeline.ingest_arrays` with no
per-event objects — the shape a real CUPTI activity-buffer flush has.
:class:`~repro.core.talp.TalpMonitor` prefers ``flush_arrays`` when a
backend provides it; implementing only ``flush`` remains valid.

Backends register by name so a deployment enables whichever matches the
runtime environment (the paper: CUPTI plugin if CUDA, rocprofiler if HIP,
OpenACC hooks if the OpenACC runtime is detected).
"""

from __future__ import annotations

from typing import Callable, Dict, Iterable, List, Protocol, Tuple, runtime_checkable

from ..states import DeviceRecord

__all__ = [
    "ActivityBackend",
    "ColumnarActivityBackend",
    "register_backend",
    "get_backend",
    "available_backends",
]


@runtime_checkable
class ActivityBackend(Protocol):
    """Protocol every plugin implements."""

    def start(self) -> None:
        """Enable collection (≙ cuptiActivityEnable / rocprofiler filters)."""
        ...

    def stop(self) -> None:
        """Disable collection and release resources."""
        ...

    def flush(self) -> Iterable[Tuple[int, DeviceRecord]]:
        """Drain buffered (device, record) pairs (≙ activity-buffer flush)."""
        ...


@runtime_checkable
class ColumnarActivityBackend(ActivityBackend, Protocol):
    """Extended protocol for backends that deliver whole column batches.

    ``flush_arrays()`` drains every buffered batch as
    ``(device, kinds, starts, ends, streams)`` tuples of equal-length
    arrays (``streams`` may be ``None`` for stream 0). A backend
    implementing this is never asked to materialize ``DeviceRecord``
    objects on the hot path.
    """

    def flush_arrays(self) -> Iterable[Tuple[int, object, object, object, object]]:
        """Drain buffered per-device column batches."""
        ...


_REGISTRY: Dict[str, Callable[..., ActivityBackend]] = {}


def register_backend(name: str):
    def deco(factory: Callable[..., ActivityBackend]):
        _REGISTRY[name] = factory
        return factory
    return deco


def get_backend(name: str, **kwargs) -> ActivityBackend:
    if name not in _REGISTRY:
        raise KeyError(f"unknown backend {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def available_backends() -> List[str]:
    return sorted(_REGISTRY)
