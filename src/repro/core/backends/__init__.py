from .base import ActivityBackend, available_backends, get_backend, register_backend
from .synthetic import SyntheticBackend, SyntheticTraceBuilder
from .runtime import RuntimeBackend
from .analytical import (
    AnalyticalBackend,
    HardwareSpec,
    StepModel,
    TPU_V5E,
    trace_from_step_model,
)

__all__ = [
    "ActivityBackend",
    "available_backends",
    "get_backend",
    "register_backend",
    "SyntheticBackend",
    "SyntheticTraceBuilder",
    "RuntimeBackend",
    "AnalyticalBackend",
    "HardwareSpec",
    "StepModel",
    "TPU_V5E",
    "trace_from_step_model",
]
