"""Multiplicative metric trees (paper Figs. 1–3).

POP metrics are organized hierarchically where each parent is the
product of its children. ``MetricNode`` captures that structure
generically; builders assemble the paper's host and device trees from
the computed metric dataclasses, and ``validate`` enforces the
multiplicative invariant (a property test target).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional

from .device_metrics import DeviceMetrics
from .host_metrics import HostMetrics

__all__ = ["MetricNode", "host_tree", "device_tree"]


@dataclass
class MetricNode:
    name: str
    value: float
    children: List["MetricNode"] = field(default_factory=list)
    # Leaf metrics that are *not* multiplicative children (annotations):
    multiplicative: bool = True

    def validate(self, tol: float = 1e-6) -> None:
        mult_children = [c for c in self.children if c.multiplicative]
        if mult_children:
            prod = 1.0
            for c in mult_children:
                prod *= c.value
            if abs(prod - self.value) > tol:
                raise AssertionError(
                    f"{self.name}: value {self.value:.6f} != product of "
                    f"children {prod:.6f}"
                )
        for c in self.children:
            c.validate(tol)

    def walk(self) -> Iterator["MetricNode"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> Optional["MetricNode"]:
        for node in self.walk():
            if node.name == name:
                return node
        return None

    def as_dict(self) -> Dict:
        return {
            "name": self.name,
            "value": self.value,
            "children": [c.as_dict() for c in self.children],
        }

    @staticmethod
    def from_dict(d: Dict) -> "MetricNode":
        return MetricNode(
            name=d["name"],
            value=d["value"],
            children=[MetricNode.from_dict(c) for c in d.get("children", [])],
        )


def host_tree(hm: HostMetrics) -> MetricNode:
    """Paper Fig. 2 (host resources); new metrics are the orange boxes."""
    return MetricNode(
        "Parallel Efficiency",
        hm.parallel_efficiency,
        children=[
            MetricNode(
                "MPI Parallel Eff.",
                hm.mpi_parallel_efficiency,
                children=[
                    MetricNode("Comm. Eff.", hm.communication_efficiency),
                    MetricNode("Load Balance", hm.load_balance),
                ],
            ),
            MetricNode("Device Offload Eff.", hm.device_offload_efficiency),
        ],
    )


def device_tree(dm: DeviceMetrics) -> MetricNode:
    """Paper Fig. 3 (device resources), Parallel Efficiency branch."""
    root = MetricNode(
        "Parallel Efficiency",
        dm.parallel_efficiency,
        children=[
            MetricNode("Load Balance", dm.load_balance),
            MetricNode("Communication Eff.", dm.communication_efficiency),
            MetricNode("Orchestration Eff.", dm.orchestration_efficiency),
        ],
    )
    if dm.computational_efficiency is not None:
        # Beyond-paper: the paper's future-work branch. Not a
        # multiplicative child of Parallel Efficiency (it is the sibling
        # branch under Device Efficiency), so mark non-multiplicative.
        root.children.append(
            MetricNode(
                "Computational Eff. (ext)",
                dm.computational_efficiency,
                multiplicative=False,
            )
        )
    return root
