"""Multiplicative metric trees (paper Figs. 1–3).

POP metrics are organized hierarchically where each parent is the
product of its children. ``MetricNode`` captures that structure
generically; the trees themselves are *derived* from the declarative
specs in :mod:`repro.core.hierarchy` (``tree_from_frame``), so the shape
lives in exactly one place. ``validate`` enforces the multiplicative
invariant (a property test target).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Iterator, List, Optional

from .hierarchy import DEVICE, HOST, MetricFrame, MetricSpec

if TYPE_CHECKING:  # façade types, for signatures only
    from .device_metrics import DeviceMetrics
    from .host_metrics import HostMetrics

__all__ = ["MetricNode", "tree_from_frame", "host_tree", "device_tree"]


@dataclass
class MetricNode:
    name: str
    value: float
    children: List["MetricNode"] = field(default_factory=list)
    # Leaf metrics that are *not* multiplicative children (annotations):
    multiplicative: bool = True

    def validate(self, tol: float = 1e-6) -> None:
        mult_children = [c for c in self.children if c.multiplicative]
        if mult_children:
            prod = 1.0
            for c in mult_children:
                prod *= c.value
            if abs(prod - self.value) > tol:
                raise AssertionError(
                    f"{self.name}: value {self.value:.6f} != product of "
                    f"children {prod:.6f}"
                )
        for c in self.children:
            c.validate(tol)

    def walk(self) -> Iterator["MetricNode"]:
        yield self
        for c in self.children:
            yield from c.walk()

    def find(self, name: str) -> Optional["MetricNode"]:
        for node in self.walk():
            if node.name == name:
                return node
        return None

    def as_dict(self) -> Dict:
        return {
            "name": self.name,
            "value": self.value,
            "children": [c.as_dict() for c in self.children],
        }

    @staticmethod
    def from_dict(d: Dict) -> "MetricNode":
        return MetricNode(
            name=d["name"],
            value=d["value"],
            children=[MetricNode.from_dict(c) for c in d.get("children", [])],
        )


def tree_from_frame(frame: MetricFrame) -> MetricNode:
    """Derive the MetricNode tree from a computed hierarchy frame.

    Non-multiplicative (annotation/extension) nodes are suffixed
    ``(ext)`` and excluded from the product invariant; optional nodes
    absent from the frame are skipped entirely.
    """

    def build(spec: MetricSpec) -> Optional[MetricNode]:
        if spec.key not in frame.values:
            return None
        name = spec.display if spec.multiplicative else f"{spec.display} (ext)"
        node = MetricNode(
            name, frame.values[spec.key], multiplicative=spec.multiplicative
        )
        for c in spec.children:
            child = build(c)
            if child is not None:
                node.children.append(child)
        return node

    root = build(frame.hierarchy.root)
    if root is None:
        raise ValueError(
            f"frame for hierarchy {frame.hierarchy.name!r} has no root value"
        )
    return root


def host_tree(hm: "HostMetrics") -> MetricNode:
    """Paper Fig. 2 (host resources); new metrics are the orange boxes."""
    return tree_from_frame(HOST.frame_of(hm))


def device_tree(dm: "DeviceMetrics") -> MetricNode:
    """Paper Fig. 3 (device resources), Parallel Efficiency branch."""
    return tree_from_frame(DEVICE.frame_of(dm))
