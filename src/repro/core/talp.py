"""TalpMonitor — the TALP measurement engine (paper §3.2, §4.2), JAX-adapted.

Mirrors TALP's design:

  * **Region API** (≙ TALP user-level API): ``with monitor.region("solver")``
    — regions may nest and re-open; a ``Global`` region always exists.
  * **Host state accounting**: explicit ``offload()`` / ``mpi()`` scopes
    (≙ CUPTI runtime callbacks / PMPI interception); everything else in
    an open region is *Useful* — exactly TALP's measurement model.
  * **Device activity records** arrive asynchronously from a pluggable
    backend (≙ CUPTI/rocprofiler activity buffers) and are
    post-processed with the paper's flattening pipeline at ``finalize``
    (or at an online ``sample()``).
  * **Online + post-mortem**: ``sample()`` returns live metrics;
    ``finalize()`` produces the full per-region report (text/JSON via
    :mod:`repro.core.report`).

Transparency: ``monitor.instrument(fn)`` wraps a jitted callable so the
application code needs no changes (≙ LD_PRELOAD).
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from . import intervals as ivx
from .device_metrics import DeviceMetrics, device_metrics
from .host_metrics import HostMetrics, host_metrics
from .states import DeviceActivity, DeviceTimeline, HostState
from .telemetry import overhead as _ovh
from .tree import MetricNode, device_tree, host_tree

__all__ = ["TalpMonitor", "RegionResult", "TalpResult", "StepCloseEvent"]


@dataclass(frozen=True)
class StepCloseEvent:
    """One region close, seen by ``on_region_close`` callbacks.

    ``index`` counts closes of *this* region (0-based) — the step index
    of the step-series row. The state durations are **per-window
    deltas**: exactly the offload/MPI charged between this open and this
    close (not the region's cumulative totals), so a one-step anomaly is
    visible at full amplitude instead of being averaged into history.
    """

    region: str
    index: int
    t_open: float
    t_close: float
    useful: float
    offload: float
    mpi: float

    @property
    def elapsed(self) -> float:
        return self.t_close - self.t_open


@dataclass
class _RegionAcc:
    """Accumulator for one (region, rank).

    ``closed_total`` is the running sum of closed-window durations,
    maintained at ``close_region`` time so ``elapsed()`` is O(1) instead
    of O(#windows). ``window_intervals`` likewise keeps a flattened-array
    cache of the closed windows and folds in only the ones appended since
    the last call — an open region samples in O(1) per new window.
    ``open_offload``/``open_mpi`` snapshot the cumulative state totals at
    ``open_region`` time so ``close_region`` can hand per-window deltas
    to the region-close callbacks.
    """

    windows: List[Tuple[float, float]] = field(default_factory=list)
    open_since: Optional[float] = None
    offload: float = 0.0
    mpi: float = 0.0
    closed_total: float = 0.0
    open_offload: float = 0.0
    open_mpi: float = 0.0
    _flat: Optional[np.ndarray] = field(
        default=None, init=False, repr=False, compare=False
    )
    _flat_n: int = field(default=0, init=False, repr=False, compare=False)

    def elapsed(self, now: Optional[float] = None) -> float:
        tot = self.closed_total
        if self.open_since is not None and now is not None:
            tot += max(0.0, now - self.open_since)
        return tot

    def window_intervals(self, now: Optional[float] = None) -> np.ndarray:
        if self._flat_n < len(self.windows):
            new = ivx.as_intervals(self.windows[self._flat_n:])
            if self._flat is not None and len(self._flat):
                new = np.concatenate([self._flat, new], axis=0)
            self._flat = ivx.flatten(new)
            self._flat_n = len(self.windows)
        flat = self._flat if self._flat is not None else ivx.EMPTY
        if self.open_since is not None and now is not None:
            open_iv = ivx.as_intervals([(self.open_since, now)])
            if not len(flat):
                return open_iv
            return ivx.flatten(np.concatenate([flat, open_iv], axis=0))
        return flat.copy()


@dataclass
class RegionResult:
    name: str
    elapsed: float
    n_ranks: int
    n_devices: int
    host: Optional[HostMetrics]
    device: Optional[DeviceMetrics]
    host_states: Dict[int, Dict[str, float]]
    device_states: Dict[int, Dict[str, float]]

    def trees(self) -> Dict[str, MetricNode]:
        out: Dict[str, MetricNode] = {}
        if self.host is not None:
            out["host"] = host_tree(self.host)
        if self.device is not None:
            out["device"] = device_tree(self.device)
        return out


@dataclass
class TalpResult:
    name: str
    regions: Dict[str, RegionResult]
    #: Partial-merge annotation (a :class:`repro.core.collect.RankCoverage`):
    #: set by tolerant job-level merges to record which ranks were
    #: expected/merged/missing/quarantined. ``None`` on per-rank results
    #: and on strict (all-ranks) merges.
    rank_coverage: Optional[object] = None

    def __getitem__(self, region: str) -> RegionResult:
        return self.regions[region]


class TalpMonitor:
    """Lightweight region/state monitor for one process ("rank")."""

    GLOBAL = "Global"

    def __init__(
        self,
        name: str = "talp",
        rank: int = 0,
        clock: Callable[[], float] = time.perf_counter,
        backend: Optional[object] = None,
        auto_start: bool = True,
        incremental: bool = True,
        overhead_report: bool = False,
        flop_model: Optional[object] = None,
    ):
        self.name = name
        self.rank = rank
        self.clock = clock
        self.backend = backend
        # Optional occupancy/FLOP source for the device hierarchy's
        # Computational Efficiency annotation: any object exposing
        # ``model_flops`` (useful FLOPs per device per kernel launch) and
        # ``hw.peak_flops`` — an analytical ``StepModel`` or a compiled
        # ``repro.roofline.RooflineReport`` both qualify, so the runtime
        # and synthetic backends get a real CE feed, not just the
        # analytical backend's synthesized traces.
        self.flop_model = flop_model
        # Self-overhead accounting: every monitor owns an accumulator and
        # installs it process-globally (last monitor wins — the
        # one-monitor-per-rank reality), so the hot paths it does not own
        # directly (DeviceTimeline.compact, backend flush, spool publish)
        # charge the same ledger. The accumulator always uses a *real*
        # monotonic clock, independent of ``clock`` (tests drive monitors
        # with synthetic clocks; the monitor's own cost is still real).
        # ``overhead_report=True`` additionally surfaces the measured
        # wall-clock fraction as the optional ``talp_overhead`` node of
        # the Global region's host hierarchy.
        self.overhead = _ovh.OverheadAccumulator()
        self.overhead_report = overhead_report
        _ovh.install(self.overhead)
        # ``incremental`` keeps the per-device flattened-interval arrays
        # cached between sample() calls, folding in only records that
        # arrived since the previous sample (via DeviceTimeline.compact).
        # Disable to force a full re-flatten per sample (the baseline the
        # merge benchmark measures against).
        self.incremental = incremental
        # region name -> rank -> accumulator  (single-process monitor has
        # one rank; merged results may carry many).
        self._acc: Dict[str, _RegionAcc] = {}
        self._region_stack: List[str] = []
        self._close_callbacks: List[Callable[["TalpMonitor", StepCloseEvent], None]] = []
        self._state: Optional[HostState] = None
        self._state_since: Optional[float] = None
        self.devices: Dict[int, DeviceTimeline] = {}
        # dev -> (n_records watermark, (kernel, memory) flattened arrays)
        self._flat_cache: Dict[int, Tuple[int, Tuple[np.ndarray, np.ndarray]]] = {}
        if backend is not None and hasattr(backend, "start"):
            backend.start()
        if auto_start:
            self.open_region(self.GLOBAL)

    # ------------------------------------------------------------------
    # Region API (TALP user-level API analogue)
    # ------------------------------------------------------------------
    def open_region(self, name: str) -> None:
        if self._state is not None:
            # A state scope's duration is charged at scope exit to the
            # regions on the stack at that moment; letting the stack
            # change mid-scope would charge the region for time before it
            # opened (or silently drop time for one closed mid-scope).
            raise RuntimeError(
                f"cannot open region {name!r} inside host state {self._state}"
            )
        acc = self._acc.setdefault(name, _RegionAcc())
        if acc.open_since is not None:
            raise RuntimeError(f"region {name!r} already open")
        acc.open_since = self.clock()
        acc.open_offload = acc.offload
        acc.open_mpi = acc.mpi
        self._region_stack.append(name)

    def on_region_close(
        self, callback: Callable[["TalpMonitor", StepCloseEvent], None]
    ) -> Callable[[], None]:
        """Register a callback fired at every ``close_region`` with a
        :class:`StepCloseEvent` (per-window state deltas) — the per-step
        sampling hook (``StepSeriesRecorder`` attaches here). Returns an
        unregister function. Callbacks run after the window is recorded,
        outside any host-state scope, and must not open/close regions."""
        self._close_callbacks.append(callback)

        def unregister() -> None:
            try:
                self._close_callbacks.remove(callback)
            except ValueError:
                pass

        return unregister

    def close_region(self, name: str) -> None:
        if self._state is not None:
            raise RuntimeError(
                f"cannot close region {name!r} inside host state {self._state}"
            )
        if not self._region_stack or self._region_stack[-1] != name:
            raise RuntimeError(
                f"region close mismatch: {name!r} vs stack {self._region_stack}"
            )
        acc = self._acc[name]
        now = self.clock()
        t_open = acc.open_since
        acc.windows.append((t_open, now))
        acc.closed_total += now - t_open
        acc.open_since = None
        self._region_stack.pop()
        if self._close_callbacks:
            d_off = acc.offload - acc.open_offload
            d_mpi = acc.mpi - acc.open_mpi
            ev = StepCloseEvent(
                region=name,
                index=len(acc.windows) - 1,
                t_open=t_open,
                t_close=now,
                useful=max(0.0, (now - t_open) - d_off - d_mpi),
                offload=d_off,
                mpi=d_mpi,
            )
            for cb in tuple(self._close_callbacks):
                cb(self, ev)

    @contextmanager
    def region(self, name: str):
        self.open_region(name)
        try:
            yield self
        finally:
            self.close_region(name)

    # ------------------------------------------------------------------
    # Host state scopes (CUPTI-runtime-callback / PMPI analogue)
    # ------------------------------------------------------------------
    @contextmanager
    def _state_scope(self, state: HostState):
        if self._state is not None:
            raise RuntimeError(f"nested host state {state} inside {self._state}")
        self._state = state
        t0 = self.clock()
        try:
            yield
        finally:
            dt = self.clock() - t0
            self._state = None
            self._charge(state, dt)

    def _charge(self, state: HostState, dt: float) -> None:
        """Charge a non-useful duration to every open region."""
        for name in self._region_stack:
            acc = self._acc[name]
            if state is HostState.OFFLOAD:
                acc.offload += dt
            elif state is HostState.MPI:
                acc.mpi += dt

    def offload(self):
        """Host blocked in device dispatch/transfer/sync."""
        return self._state_scope(HostState.OFFLOAD)

    def mpi(self):
        """Host blocked waiting on other ranks (control-plane sync)."""
        return self._state_scope(HostState.MPI)

    # ------------------------------------------------------------------
    # Device records
    # ------------------------------------------------------------------
    def device(self, dev: int) -> DeviceTimeline:
        if dev not in self.devices:
            self.devices[dev] = DeviceTimeline(device=dev)
        return self.devices[dev]

    def add_device_record(
        self, dev: int, kind: DeviceActivity, start: float, end: float,
        stream: int = 0, name: str = "",
    ) -> None:
        self.device(dev).add(kind, start, end, stream, name)

    def ingest_device_arrays(
        self, dev: int, kinds, starts, ends, streams=None
    ) -> int:
        """Batch entry point: deliver one whole activity buffer for a
        device as columns (see :meth:`DeviceTimeline.ingest_arrays`)."""
        t0 = self.overhead.begin()
        try:
            return self.device(dev).ingest_arrays(kinds, starts, ends, streams)
        finally:
            self.overhead.end("ingest", t0)

    def _flush_backend(self) -> None:
        be = self.backend
        if be is None:
            return
        t0 = self.overhead.begin()
        try:
            if hasattr(be, "flush_arrays"):
                # Columnar path: whole activity buffers, zero per-event objects.
                for dev, kinds, starts, ends, streams in be.flush_arrays():
                    self.device(dev).ingest_arrays(kinds, starts, ends, streams)
            elif hasattr(be, "flush"):
                # Legacy object path: batch per device before ingesting.
                by_dev: Dict[int, List] = {}
                for dev, rec in be.flush():
                    by_dev.setdefault(dev, []).append(rec)
                for dev, recs in by_dev.items():
                    self.device(dev).ingest(recs)
        finally:
            self.overhead.end("ingest", t0)

    # ------------------------------------------------------------------
    # Transparent instrumentation
    # ------------------------------------------------------------------
    def instrument(self, fn: Callable, device: int = 0, name: str = "") -> Callable:
        """Wrap a (jitted) callable: host time blocked on it = Offload,
        the execution window = a device Kernel record.

        When a backend with ``launch``/``wait`` is attached, dispatch is
        routed through it so the device record comes from the backend's
        activity buffer (launch→ready), decoupled from the host-blocked
        window. Without a backend the kernel record is *synthesized* to
        span exactly the host-blocked window — an approximation that by
        construction pins Orchestration Efficiency (max(K+M)/E) to 1 over
        that window, so device metrics from backend-less instrumentation
        only carry information about idle gaps *between* calls.
        """
        label = name or getattr(fn, "__name__", "fn")
        backend = self.backend
        if (backend is not None and hasattr(backend, "launch")
                and hasattr(backend, "wait")):

            def wrapped(*args, **kwargs):
                # The host is blocked for the whole wrapped call (dispatch,
                # possible first-call compilation, and the wait), so all of
                # it is Offload; the backend owns the device record timing.
                # The closure keeps the caller's kwargs for fn separate
                # from launch()'s own device/name/stream parameters.
                with self.offload():
                    handle = backend.launch(
                        lambda: fn(*args, **kwargs), device=device, name=label
                    )
                    return backend.wait(handle)

        else:

            def wrapped(*args, **kwargs):
                import jax

                t0 = self.clock()
                with self.offload():
                    out = fn(*args, **kwargs)
                    out = jax.block_until_ready(out)
                t1 = self.clock()
                self.add_device_record(
                    device, DeviceActivity.KERNEL, t0, t1, name=label
                )
                return out

        wrapped.__name__ = f"talp_{label}"
        return wrapped

    # ------------------------------------------------------------------
    # Results
    # ------------------------------------------------------------------
    def _device_flats(self) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
        """Per-device flattened (kernel, memory-minus-kernel) intervals —
        the region-independent part of the post-processing, computed once
        per sample()/finalize() and shared across regions.

        In incremental mode a per-device cache keyed on the timeline's
        ``n_records`` watermark makes repeated sampling cheap: new raw
        records are first folded into the timeline's compacted arrays
        (reusing the ``compact_threshold`` streaming machinery), the
        flattened pair is rebuilt from those, and an unchanged timeline
        is a pure cache hit — no re-flattening of the whole history.
        """
        t0 = self.overhead.begin()
        try:
            flats: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
            for dev, tl in sorted(self.devices.items()):
                if self.incremental:
                    cached = self._flat_cache.get(dev)
                    if cached is not None and cached[0] == tl.n_records:
                        flats[dev] = cached[1]
                        continue
                    tl.compact()  # fold pending records once, incrementally
                kern = tl.kind_intervals(DeviceActivity.KERNEL)
                mem = ivx.subtract(tl.kind_intervals(DeviceActivity.MEMORY), kern)
                flats[dev] = (kern, mem)
                if self.incremental:
                    self._flat_cache[dev] = (tl.n_records, flats[dev])
            return flats
        finally:
            self.overhead.end("flatten", t0)

    def computational_efficiency(
        self,
        device_flats: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
    ) -> Optional[float]:
        """Measured Device Computational Efficiency from the attached
        ``flop_model``: useful FLOPs executed (kernel launches ×
        ``model_flops``) over peak throughput during the measured kernel
        busy time — ``None`` without a model or kernel activity. CE is a
        property of the kernels themselves, so the single monitor-wide
        value annotates every region's device frame."""
        fm = self.flop_model
        if fm is None:
            return None
        peak = float(getattr(getattr(fm, "hw", None), "peak_flops", 0.0) or 0.0)
        model_flops = float(getattr(fm, "model_flops", 0.0) or 0.0)
        if peak <= 0 or model_flops <= 0:
            return None
        if device_flats is None:
            device_flats = self._device_flats()
        # flats are already flattened — direct sum, no revalidation
        busy = sum(
            float(np.sum(kern[:, 1] - kern[:, 0]))
            for kern, _ in device_flats.values()
        )
        launches = sum(
            self.devices[d].n_kernel_records for d in device_flats
            if d in self.devices
        )
        if busy <= 0 or launches == 0:
            return None
        return (launches * model_flops) / (peak * busy)

    def _region_result(
        self,
        name: str,
        now: Optional[float],
        device_flats: Optional[Dict[int, Tuple[np.ndarray, np.ndarray]]] = None,
    ) -> RegionResult:
        acc = self._acc[name]
        elapsed = acc.elapsed(now)
        windows = acc.window_intervals(now)
        useful = max(0.0, elapsed - acc.offload - acc.mpi)
        hm = (
            host_metrics(
                [useful], [acc.offload], [acc.mpi], elapsed=elapsed,
                # Self-cost is a wall-clock fraction, so it only makes
                # sense against the whole-run window: annotate Global.
                talp_overhead=(
                    self.overhead.fraction(elapsed)
                    if self.overhead_report and name == self.GLOBAL
                    else None
                ),
            )
            if elapsed > 0
            else None
        )
        dev_states: Dict[int, Dict[str, float]] = {}
        kernels: List[float] = []
        memories: List[float] = []
        if device_flats is None:
            device_flats = self._device_flats()
        for dev, (kern, mem) in sorted(device_flats.items()):
            k_in = ivx.total(ivx.intersect(kern, windows)) if len(windows) else 0.0
            m_in = ivx.total(ivx.intersect(mem, windows)) if len(windows) else 0.0
            idle = max(0.0, elapsed - k_in - m_in)
            dev_states[dev] = {"kernel": k_in, "memory": m_in, "idle": idle}
            kernels.append(k_in)
            memories.append(m_in)
        dm = (
            device_metrics(
                kernels, memories, elapsed,
                computational_efficiency=self.computational_efficiency(
                    device_flats
                ),
            )
            if kernels and elapsed > 0
            else None
        )
        return RegionResult(
            name=name,
            elapsed=elapsed,
            n_ranks=1,
            n_devices=len(kernels),
            host=hm,
            device=dm,
            host_states={self.rank: {"useful": useful, "offload": acc.offload, "mpi": acc.mpi}},
            device_states=dev_states,
        )

    def region_windows(
        self, now: Optional[float] = None
    ) -> Dict[str, np.ndarray]:
        """Absolute (monitor-clock) flattened window arrays per region —
        open regions extend to ``now``. The exact timestamps the trace
        exporter turns into region begin/end markers."""
        if now is None:
            now = self.clock()
        return {
            name: acc.window_intervals(now) for name, acc in self._acc.items()
        }

    def sample(self, region: Optional[str] = None) -> RegionResult:
        """Online metrics for an open (or closed) region — TALP's runtime mode."""
        t0 = self.overhead.begin()
        try:
            self._flush_backend()
            return self._region_result(
                region or self.GLOBAL, now=self.clock(),
                device_flats=self._device_flats(),
            )
        finally:
            self.overhead.end("sample", t0)

    def sample_result(self) -> TalpResult:
        """Non-destructive all-regions snapshot at the current clock — the
        per-rank payload for :func:`repro.core.merge.merge_samples`.

        Open regions are measured up to *now*; nothing is closed and the
        monitor keeps running, so snapshots can be taken repeatedly during
        the run (e.g. on a ``--talp-sample-every`` cadence) and merged
        across ranks into a job-level mid-run report.
        """
        t0 = self.overhead.begin()
        try:
            self._flush_backend()
            now = self.clock()
            flats = self._device_flats()
            regions = {
                name: self._region_result(name, now=now, device_flats=flats)
                for name in self._acc
            }
            return TalpResult(name=self.name, regions=regions)
        finally:
            self.overhead.end("sample", t0)

    def finalize(self) -> TalpResult:
        """Close remaining regions and produce the post-mortem result."""
        now = self.clock()
        while self._region_stack:
            self.close_region(self._region_stack[-1])
        t0 = self.overhead.begin()
        try:
            self._flush_backend()
            if self.backend is not None and hasattr(self.backend, "stop"):
                self.backend.stop()
            flats = self._device_flats()
            regions = {
                name: self._region_result(name, now=None, device_flats=flats)
                for name in self._acc
            }
            return TalpResult(name=self.name, regions=regions)
        finally:
            self.overhead.end("sample", t0)
