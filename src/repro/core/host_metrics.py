"""Host-side efficiency hierarchy for accelerated platforms (paper §4.1).

Extends the POP host hierarchy (Fig. 2). Three host states per rank:
Useful (U), Device Offloading (W), MPI. New metrics (orange boxes):

  Host Hybrid Parallel Efficiency  PE_host = ΣU / (E·n)              (eq. 6)
  MPI Parallel Efficiency          MPI_PE = Σ(U+W) / (E·n)           (eq. 7)
  Device Offload Efficiency        OE_host = ΣU / Σ(U+W)             (eq. 8)

with PE_host = MPI_PE × OE_host. MPI_PE's children apply "the same
treatment of states" (offload counts as useful):

  Load Balance           LB = Σ(U+W) / (n · max(U+W))
  Communication Eff.     CE = max(U+W) / E

so MPI_PE = LB × CE, mirroring the original POP formulas. The formulas
live in :data:`repro.core.hierarchy.HOST`; this module is the input-
validating façade around them.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from .hierarchy import HOST, MetricFrame, StateDurations, elapsed_time

__all__ = ["HostMetrics", "host_metrics"]


@dataclass(frozen=True)
class HostMetrics:
    parallel_efficiency: float        # PE_host, eq. (6)
    mpi_parallel_efficiency: float    # eq. (7)
    communication_efficiency: float   # child of MPI PE
    load_balance: float               # child of MPI PE
    device_offload_efficiency: float  # eq. (8)
    elapsed: float
    n_processes: int
    # optional annotation: monitor self-cost fraction (absent → None)
    talp_overhead: Optional[float] = None

    @classmethod
    def from_frame(cls, frame: MetricFrame) -> "HostMetrics":
        return cls(**frame.scalar_fields())

    def frame(self) -> MetricFrame:
        return HOST.frame_of(self)

    def validate(self, tol: float = 1e-9) -> None:
        self.frame().validate(tol)

    def as_dict(self) -> Dict[str, float]:
        return self.frame().as_dict()


def host_metrics(
    useful: Sequence[float],
    offload: Sequence[float],
    mpi: Optional[Sequence[float]] = None,
    elapsed: Optional[float] = None,
    talp_overhead: Optional[float] = None,
) -> HostMetrics:
    """Compute eqs. (6)–(8) plus the MPI-PE children.

    ``elapsed`` defaults to paper eq. (1) over the three-state totals.
    ``talp_overhead`` (monitor self-cost fraction of wall-clock) feeds
    the optional annotation node of the same name.
    """
    u = np.asarray(useful, dtype=np.float64)
    w = np.asarray(offload, dtype=np.float64)
    if u.shape != w.shape or u.ndim != 1 or len(u) == 0:
        raise ValueError("useful/offload must be equal-length 1-D, non-empty")
    if np.any(u < 0) or np.any(w < 0):
        raise ValueError("negative state duration")
    if elapsed is None:
        if mpi is None:
            raise ValueError("need mpi durations or explicit elapsed")
        m = np.asarray(mpi, dtype=np.float64)
        elapsed = elapsed_time(u, w + m)
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    extras = {} if talp_overhead is None else {"talp_overhead": float(talp_overhead)}
    sd = StateDurations(
        elapsed=float(elapsed), useful=u, offload=w, mpi=mpi, extras=extras
    )
    return HostMetrics.from_frame(HOST.compute(sd))
