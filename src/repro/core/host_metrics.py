"""Host-side efficiency hierarchy for accelerated platforms (paper §4.1).

Extends the POP host hierarchy (Fig. 2). Three host states per rank:
Useful (U), Device Offloading (W), MPI. New metrics (orange boxes):

  Host Hybrid Parallel Efficiency  PE_host = ΣU / (E·n)              (eq. 6)
  MPI Parallel Efficiency          MPI_PE = Σ(U+W) / (E·n)           (eq. 7)
  Device Offload Efficiency        OE_host = ΣU / Σ(U+W)             (eq. 8)

with PE_host = MPI_PE × OE_host. MPI_PE's children apply "the same
treatment of states" (offload counts as useful):

  Load Balance           LB = Σ(U+W) / (n · max(U+W))
  Communication Eff.     CE = max(U+W) / E

so MPI_PE = LB × CE, mirroring the original POP formulas.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from .pop import elapsed_time

__all__ = ["HostMetrics", "host_metrics"]


@dataclass(frozen=True)
class HostMetrics:
    parallel_efficiency: float        # PE_host, eq. (6)
    mpi_parallel_efficiency: float    # eq. (7)
    communication_efficiency: float   # child of MPI PE
    load_balance: float               # child of MPI PE
    device_offload_efficiency: float  # eq. (8)
    elapsed: float
    n_processes: int

    def validate(self, tol: float = 1e-9) -> None:
        p1 = self.mpi_parallel_efficiency * self.device_offload_efficiency
        if abs(p1 - self.parallel_efficiency) > tol:
            raise AssertionError(f"PE_host {self.parallel_efficiency} != MPI_PE*OE {p1}")
        p2 = self.load_balance * self.communication_efficiency
        if abs(p2 - self.mpi_parallel_efficiency) > tol:
            raise AssertionError(f"MPI_PE {self.mpi_parallel_efficiency} != LB*CE {p2}")

    def as_dict(self) -> Dict[str, float]:
        return {
            "parallel_efficiency": self.parallel_efficiency,
            "mpi_parallel_efficiency": self.mpi_parallel_efficiency,
            "communication_efficiency": self.communication_efficiency,
            "load_balance": self.load_balance,
            "device_offload_efficiency": self.device_offload_efficiency,
            "elapsed": self.elapsed,
            "n_processes": self.n_processes,
        }


def host_metrics(
    useful: Sequence[float],
    offload: Sequence[float],
    mpi: Optional[Sequence[float]] = None,
    elapsed: Optional[float] = None,
) -> HostMetrics:
    """Compute eqs. (6)–(8) plus the MPI-PE children.

    ``elapsed`` defaults to paper eq. (1) over the three-state totals.
    """
    u = np.asarray(useful, dtype=np.float64)
    w = np.asarray(offload, dtype=np.float64)
    if u.shape != w.shape or u.ndim != 1 or len(u) == 0:
        raise ValueError("useful/offload must be equal-length 1-D, non-empty")
    if np.any(u < 0) or np.any(w < 0):
        raise ValueError("negative state duration")
    n = len(u)
    if elapsed is None:
        if mpi is None:
            raise ValueError("need mpi durations or explicit elapsed")
        m = np.asarray(mpi, dtype=np.float64)
        elapsed = elapsed_time(u, w + m)
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    uw = u + w
    sum_u = float(np.sum(u))
    sum_uw = float(np.sum(uw))
    max_uw = float(np.max(uw))
    pe_host = sum_u / (elapsed * n)                              # eq. (6)
    mpi_pe = sum_uw / (elapsed * n)                              # eq. (7)
    oe = sum_u / sum_uw if sum_uw > 0 else 0.0                   # eq. (8)
    lb = sum_uw / (n * max_uw) if max_uw > 0 else 0.0
    ce = max_uw / elapsed
    return HostMetrics(
        parallel_efficiency=pe_host,
        mpi_parallel_efficiency=mpi_pe,
        communication_efficiency=ce,
        load_balance=lb,
        device_offload_efficiency=oe,
        elapsed=float(elapsed),
        n_processes=n,
    )
