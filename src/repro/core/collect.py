"""Fault-tolerant distributed collection: coverage accounting, spool
quarantine, straggler deadlines, and deterministic fault injection.

Production job monitoring treats partial data as the common case, not an
error: ranks die, filesystems drop writes mid-file, stragglers arrive
after the deadline. This module gives the collection layer
(:mod:`repro.core.merge`) the vocabulary to *describe* those losses
instead of crashing on them:

  * :class:`RankCoverage` — the job report's ``rank_coverage`` node:
    which ranks were expected, which merged, which are missing, which
    payloads were quarantined (and why). Carried through the report JSON
    round trip, the text report, the telemetry exporter and the Chrome
    trace metadata.
  * :class:`QuarantinedSpool` + :func:`read_spool_payload` /
    :func:`quarantine_spool` — classify any unreadable spool payload
    (truncated NPZ, zero-byte file, version mismatch, mangled JSON …)
    with a human-readable reason and move it aside so a re-merge of the
    directory stays clean.
  * :func:`wait_for_ranks` — deadline-based wait for stragglers with
    exponential poll backoff; returns whatever arrived by the deadline
    (never raises).
  * :class:`FaultPlan` — a *deterministic* fault-injection layer (drop a
    rank, truncate/corrupt its payload bytes, delay its submit, skew its
    clock) usable from tests, benchmarks and the drivers'
    ``--talp-fault-plan`` debug flag. No randomness anywhere: a plan is
    an explicit JSON spec, so every injected failure reproduces exactly.
"""

from __future__ import annotations

import json
import os
import shutil
import time
import zipfile
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

__all__ = [
    "SpoolPayloadError",
    "SpoolVersionError",
    "QuarantinedSpool",
    "RankCoverage",
    "read_spool_payload",
    "quarantine_spool",
    "wait_for_ranks",
    "FaultPlan",
]

#: Subdirectory (of the spool dir) unreadable payloads are moved into.
QUARANTINE_DIRNAME = "quarantine"


class SpoolPayloadError(ValueError):
    """A spool payload could not be read; ``reason`` is a short
    human-readable classification (stable enough to grep logs for)."""

    def __init__(self, reason: str, detail: str = ""):
        self.reason = reason
        self.detail = detail
        super().__init__(f"{reason}{': ' + detail if detail else ''}")


class SpoolVersionError(SpoolPayloadError):
    """Payload carries a ``SPOOL_BINARY_VERSION`` this reader does not
    support (raised by the binary decoder, classified here)."""

    def __init__(self, detail: str = ""):
        super().__init__("unsupported spool payload version", detail)


@dataclass(frozen=True)
class QuarantinedSpool:
    """One payload the collector refused to merge, and why."""

    path: str
    reason: str
    rank: Optional[int] = None
    quarantined_to: Optional[str] = None

    def as_dict(self) -> Dict:
        d = {"path": os.path.basename(self.path), "reason": self.reason}
        if self.rank is not None:
            d["rank"] = self.rank
        if self.quarantined_to is not None:
            d["quarantined_to"] = self.quarantined_to
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "QuarantinedSpool":
        return cls(
            path=d.get("path", ""),
            reason=d.get("reason", "unknown"),
            rank=d.get("rank"),
            quarantined_to=d.get("quarantined_to"),
        )


@dataclass
class RankCoverage:
    """Which ranks the job report actually covers.

    ``expected`` is the job's world size (``None`` while unknown — the
    constructor helpers infer the densest consistent value from the
    observed rank ids). ``merged`` + ``missing`` + ranks of
    ``quarantined`` partition ``range(expected)`` when every rank id is
    known: *missing* ranks left no payload at all, *quarantined* ones
    left one the collector could not read.
    """

    expected: Optional[int]
    merged: List[int] = field(default_factory=list)
    missing: List[int] = field(default_factory=list)
    quarantined: List[QuarantinedSpool] = field(default_factory=list)

    @classmethod
    def compute(
        cls,
        merged: Sequence[int],
        expected: Optional[int] = None,
        quarantined: Sequence[QuarantinedSpool] = (),
    ) -> "RankCoverage":
        """Derive the missing set: every rank in ``range(expected)`` that
        neither merged nor left a quarantined payload. With no explicit
        ``expected``, the densest consistent world size (max observed
        rank id + 1) is inferred — ranks *above* every observed id are
        undetectable without an explicit expectation."""
        merged = sorted(set(int(r) for r in merged))
        quarantined = list(quarantined)
        seen = set(merged) | {
            q.rank for q in quarantined if q.rank is not None
        }
        if expected is None:
            expected = (max(seen) + 1) if seen else 0
        accounted = set(merged) | {
            q.rank for q in quarantined if q.rank is not None
        }
        missing = sorted(set(range(expected)) - accounted)
        return cls(
            expected=expected, merged=merged, missing=missing,
            quarantined=quarantined,
        )

    @property
    def complete(self) -> bool:
        return not self.missing and not self.quarantined

    def summary(self) -> str:
        exp = "?" if self.expected is None else str(self.expected)
        return f"{len(self.merged)}/{exp} rank(s) merged"

    def as_dict(self) -> Dict:
        return {
            "expected": self.expected,
            "merged": list(self.merged),
            "missing": list(self.missing),
            "quarantined": [q.as_dict() for q in self.quarantined],
        }

    @classmethod
    def from_dict(cls, d: Dict) -> "RankCoverage":
        return cls(
            expected=d.get("expected"),
            merged=[int(r) for r in d.get("merged") or []],
            missing=[int(r) for r in d.get("missing") or []],
            quarantined=[
                QuarantinedSpool.from_dict(q)
                for q in d.get("quarantined") or []
            ],
        )

    def render_text(self) -> str:
        """The text-report coverage block (see ``report.render_tables``)."""
        lines = [f"rank coverage: {self.summary()}"]
        if self.missing:
            lines.append(
                "  missing rank(s)    : "
                + ", ".join(str(r) for r in self.missing)
            )
        for q in self.quarantined:
            who = f"rank {q.rank}" if q.rank is not None else "unknown rank"
            lines.append(
                f"  quarantined payload: {who} "
                f"({os.path.basename(q.path)}): {q.reason}"
            )
        if self.complete:
            lines.append("  all expected ranks merged")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# defensive payload reading + quarantine
# ---------------------------------------------------------------------------
def read_spool_payload(path: str):
    """Read one spool file like ``merge.load_spool_payload`` but map
    every failure mode to a :class:`SpoolPayloadError` whose ``reason``
    names the corruption class — the collector's single choke point for
    deciding "merge or quarantine". Returns ``(result, timelines)``."""
    from .merge import load_spool_payload

    try:
        size = os.path.getsize(path)
    except OSError as e:
        raise SpoolPayloadError("unreadable file", str(e)) from e
    if size == 0:
        raise SpoolPayloadError("zero-byte file")
    try:
        return load_spool_payload(path)
    except SpoolPayloadError:
        raise
    except (zipfile.BadZipFile, EOFError) as e:
        raise SpoolPayloadError(
            "truncated or non-NPZ binary payload", str(e)
        ) from e
    except json.JSONDecodeError as e:
        raise SpoolPayloadError("mangled JSON payload", str(e)) from e
    except UnicodeDecodeError as e:
        raise SpoolPayloadError("undecodable payload text", str(e)) from e
    except (KeyError, IndexError, TypeError, ValueError, OSError) as e:
        # np.load raises plain ValueError on mangled NPZ members; a
        # structurally wrong header lands in KeyError/TypeError.
        raise SpoolPayloadError(
            "malformed payload structure", f"{type(e).__name__}: {e}"
        ) from e


def quarantine_spool(
    path: str, reason: str, quarantine_dir: Optional[str] = None
) -> Optional[str]:
    """Move an unreadable payload into ``<dir>/quarantine/`` (with a
    ``.reason.json`` sidecar recording why) so re-merging the spool
    directory stays clean. Best-effort: on any filesystem error the file
    is left in place and ``None`` is returned — quarantine must never
    introduce a new crash into the collection path."""
    qdir = quarantine_dir or os.path.join(
        os.path.dirname(path) or ".", QUARANTINE_DIRNAME
    )
    try:
        os.makedirs(qdir, exist_ok=True)
        dest = os.path.join(qdir, os.path.basename(path))
        shutil.move(path, dest)
        with open(dest + ".reason.json", "w") as f:
            json.dump({"path": os.path.basename(path), "reason": reason}, f)
        return dest
    except OSError:
        return None


# ---------------------------------------------------------------------------
# straggler deadline
# ---------------------------------------------------------------------------
def wait_for_ranks(
    list_ranks: Callable[[], List[int]],
    world_size: Optional[int],
    max_wait: float,
    poll: float = 0.05,
    backoff: float = 2.0,
    max_poll: float = 1.0,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
) -> List[int]:
    """Poll ``list_ranks()`` until ``world_size`` ranks are present or
    ``max_wait`` seconds elapse, with exponential poll backoff (``poll``
    doubling up to ``max_poll``). Returns the final rank list — whatever
    arrived by the deadline; deciding whether that is enough is the
    caller's policy (``allow_missing``), not this function's."""
    deadline = clock() + max(0.0, max_wait)
    ranks = list_ranks()
    while world_size is not None and len(ranks) < world_size:
        remaining = deadline - clock()
        if remaining <= 0:
            break
        sleep(min(poll, remaining))
        poll = min(poll * backoff, max_poll)
        ranks = list_ranks()
    return ranks


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------
@dataclass
class FaultPlan:
    """A reproducible fault-injection plan, keyed by rank id.

    Spec (JSON object, every section optional)::

        {
          "drop": [2],                  # ranks that never submit
          "truncate": {"1": 96},       # keep only the first N bytes
          "corrupt": {"0": {"offset": 64, "length": 16, "xor": 255}},
          "delay": {"1": 0.25},        # seconds to sleep before submit
          "clock_skew": {"0": 1.5}     # seconds added to the rank clock
        }

    ``from_spec`` accepts the dict itself, a JSON string, or ``@path`` /
    an existing file path pointing at a JSON file — the form the drivers'
    ``--talp-fault-plan`` flag takes. Everything is explicit: no RNG, so
    a failing CI scenario replays bit-identically.
    """

    drop: List[int] = field(default_factory=list)
    truncate: Dict[int, int] = field(default_factory=dict)
    corrupt: Dict[int, Dict[str, int]] = field(default_factory=dict)
    delay: Dict[int, float] = field(default_factory=dict)
    clock_skew: Dict[int, float] = field(default_factory=dict)

    @classmethod
    def from_spec(cls, spec) -> "FaultPlan":
        if isinstance(spec, FaultPlan):
            return spec
        if isinstance(spec, str):
            text = spec
            if spec.startswith("@"):
                with open(spec[1:]) as f:
                    text = f.read()
            elif not spec.lstrip().startswith("{") and os.path.exists(spec):
                with open(spec) as f:
                    text = f.read()
            try:
                spec = json.loads(text)
            except json.JSONDecodeError as e:
                raise ValueError(
                    f"fault plan is neither a JSON object nor a readable "
                    f"JSON file: {e}"
                ) from e
        if not isinstance(spec, dict):
            raise ValueError(f"fault plan spec must be a JSON object, "
                             f"got {type(spec).__name__}")
        known = {"drop", "truncate", "corrupt", "delay", "clock_skew"}
        unknown = set(spec) - known
        if unknown:
            raise ValueError(
                f"unknown fault plan section(s) {sorted(unknown)}; "
                f"known: {sorted(known)}"
            )
        return cls(
            drop=[int(r) for r in spec.get("drop") or []],
            truncate={
                int(r): int(n) for r, n in (spec.get("truncate") or {}).items()
            },
            corrupt={
                int(r): {k: int(v) for k, v in c.items()}
                for r, c in (spec.get("corrupt") or {}).items()
            },
            delay={
                int(r): float(s) for r, s in (spec.get("delay") or {}).items()
            },
            clock_skew={
                int(r): float(s)
                for r, s in (spec.get("clock_skew") or {}).items()
            },
        )

    # -- queries ---------------------------------------------------------
    def drops(self, rank: int) -> bool:
        return rank in self.drop

    def delay_s(self, rank: int) -> float:
        return self.delay.get(rank, 0.0)

    def skew_s(self, rank: int) -> float:
        return self.clock_skew.get(rank, 0.0)

    def touches(self, rank: int) -> bool:
        return (
            self.drops(rank) or rank in self.truncate
            or rank in self.corrupt or rank in self.delay
            or rank in self.clock_skew
        )

    # -- application -----------------------------------------------------
    def mutate_bytes(self, data: bytes, rank: int) -> Optional[bytes]:
        """The plan's effect on an in-memory payload: ``None`` when the
        rank is dropped, otherwise the (possibly truncated/corrupted)
        bytes. Used by array-exchange transports and tests."""
        if self.drops(rank):
            return None
        if rank in self.truncate:
            data = data[: max(0, self.truncate[rank])]
        if rank in self.corrupt:
            c = self.corrupt[rank]
            off = c.get("offset", 0)
            length = c.get("length", 1)
            x = c.get("xor", 0xFF)
            buf = bytearray(data)
            for i in range(off, min(len(buf), off + length)):
                buf[i] ^= x
            data = bytes(buf)
        return data

    def apply_to_file(self, path: str, rank: int) -> Optional[str]:
        """Apply truncate/corrupt sections to an already-published spool
        file in place; returns a description of what was done (``None``
        when the plan leaves this rank's file untouched)."""
        done = []
        if rank in self.truncate:
            os.truncate(path, max(0, self.truncate[rank]))
            done.append(f"truncated to {max(0, self.truncate[rank])}B")
        if rank in self.corrupt:
            c = self.corrupt[rank]
            off = c.get("offset", 0)
            length = c.get("length", 1)
            x = c.get("xor", 0xFF)
            with open(path, "r+b") as f:
                f.seek(0, os.SEEK_END)
                size = f.tell()
                n = max(0, min(size - off, length))
                if n:
                    f.seek(off)
                    chunk = bytearray(f.read(n))
                    for i in range(len(chunk)):
                        chunk[i] ^= x
                    f.seek(off)
                    f.write(bytes(chunk))
            done.append(f"xor-corrupted {length}B at offset {off}")
        return "; ".join(done) if done else None

    def describe(self, rank: int) -> str:
        """Human-readable summary of this rank's injected faults."""
        parts = []
        if self.drops(rank):
            parts.append("drop submit")
        if rank in self.truncate:
            parts.append(f"truncate to {self.truncate[rank]}B")
        if rank in self.corrupt:
            parts.append("corrupt bytes")
        if self.delay_s(rank):
            parts.append(f"delay submit {self.delay_s(rank)}s")
        if self.skew_s(rank):
            parts.append(f"clock skew {self.skew_s(rank):+}s")
        return ", ".join(parts) if parts else "no faults"
