"""Device-side efficiency hierarchy (paper §4.1, Fig. 3, eqs. 9–12).

Three device states per accelerator (streams flattened): Kernel (K),
Memory operations (M), Idle. The Parallel Efficiency branch:

  Device Parallel Efficiency  PE = ΣK / (E·m)                      (eq. 9)
  Load Balance                LB = ΣK / (m · max K)                (eq. 10)
  Communication Efficiency    CE = max K / max(K+M)                (eq. 11)
  Orchestration Efficiency    OE = max(K+M) / E                    (eq. 12)

with PE = LB × CE × OE (multiplicative). The second branch, Device
Computational Efficiency, is the paper's *future work*; we implement it
as a beyond-paper extension in :mod:`repro.core.backends.analytical`
(useful-model-FLOPs vs peak over kernel time) and feed it into the
hierarchy as an optional annotation node. The formulas live in
:data:`repro.core.hierarchy.DEVICE`; this module is the input-validating
façade around them.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

from .hierarchy import DEVICE, MetricFrame, StateDurations

__all__ = ["DeviceMetrics", "device_metrics"]


@dataclass(frozen=True)
class DeviceMetrics:
    parallel_efficiency: float        # eq. (9)
    load_balance: float               # eq. (10)
    communication_efficiency: float   # eq. (11)
    orchestration_efficiency: float   # eq. (12)
    elapsed: float
    n_devices: int
    # beyond-paper (paper's future-work branch), optional:
    computational_efficiency: Optional[float] = None

    @classmethod
    def from_frame(cls, frame: MetricFrame) -> "DeviceMetrics":
        return cls(**frame.scalar_fields())

    def frame(self) -> MetricFrame:
        return DEVICE.frame_of(self)

    def validate(self, tol: float = 1e-9) -> None:
        self.frame().validate(tol)

    def as_dict(self) -> Dict[str, float]:
        return self.frame().as_dict()


def device_metrics(
    kernel: Sequence[float],
    memory: Sequence[float],
    elapsed: float,
    computational_efficiency: Optional[float] = None,
) -> DeviceMetrics:
    """Compute eqs. (9)–(12) from per-device flattened state durations."""
    k = np.asarray(kernel, dtype=np.float64)
    mem = np.asarray(memory, dtype=np.float64)
    if k.shape != mem.shape or k.ndim != 1 or len(k) == 0:
        raise ValueError("kernel/memory must be equal-length 1-D, non-empty")
    if np.any(k < 0) or np.any(mem < 0):
        raise ValueError("negative state duration")
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    extras = (
        {"computational_efficiency": computational_efficiency}
        if computational_efficiency is not None
        else {}
    )
    sd = StateDurations(elapsed=float(elapsed), kernel=k, memory=mem, extras=extras)
    return DeviceMetrics.from_frame(DEVICE.compute(sd))
