"""Device-side efficiency hierarchy (paper §4.1, Fig. 3, eqs. 9–12).

Three device states per accelerator (streams flattened): Kernel (K),
Memory operations (M), Idle. The Parallel Efficiency branch:

  Device Parallel Efficiency  PE = ΣK / (E·m)                      (eq. 9)
  Load Balance                LB = ΣK / (m · max K)                (eq. 10)
  Communication Efficiency    CE = max K / max(K+M)                (eq. 11)
  Orchestration Efficiency    OE = max(K+M) / E                    (eq. 12)

with PE = LB × CE × OE (multiplicative). The second branch, Device
Computational Efficiency, is the paper's *future work*; we implement it
as a beyond-paper extension in :mod:`repro.core.backends.analytical`
(useful-model-FLOPs vs peak over kernel time).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence

import numpy as np

__all__ = ["DeviceMetrics", "device_metrics"]


@dataclass(frozen=True)
class DeviceMetrics:
    parallel_efficiency: float        # eq. (9)
    load_balance: float               # eq. (10)
    communication_efficiency: float   # eq. (11)
    orchestration_efficiency: float   # eq. (12)
    elapsed: float
    n_devices: int
    # beyond-paper (paper's future-work branch), optional:
    computational_efficiency: Optional[float] = None

    def validate(self, tol: float = 1e-9) -> None:
        prod = (
            self.load_balance
            * self.communication_efficiency
            * self.orchestration_efficiency
        )
        if abs(prod - self.parallel_efficiency) > tol:
            raise AssertionError(
                f"PE_device {self.parallel_efficiency} != LB*CE*OE {prod}"
            )

    def as_dict(self) -> Dict[str, float]:
        d = {
            "parallel_efficiency": self.parallel_efficiency,
            "load_balance": self.load_balance,
            "communication_efficiency": self.communication_efficiency,
            "orchestration_efficiency": self.orchestration_efficiency,
            "elapsed": self.elapsed,
            "n_devices": self.n_devices,
        }
        if self.computational_efficiency is not None:
            d["computational_efficiency"] = self.computational_efficiency
        return d


def device_metrics(
    kernel: Sequence[float],
    memory: Sequence[float],
    elapsed: float,
    computational_efficiency: Optional[float] = None,
) -> DeviceMetrics:
    """Compute eqs. (9)–(12) from per-device flattened state durations."""
    k = np.asarray(kernel, dtype=np.float64)
    mem = np.asarray(memory, dtype=np.float64)
    if k.shape != mem.shape or k.ndim != 1 or len(k) == 0:
        raise ValueError("kernel/memory must be equal-length 1-D, non-empty")
    if np.any(k < 0) or np.any(mem < 0):
        raise ValueError("negative state duration")
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    m = len(k)
    max_k = float(np.max(k))
    max_km = float(np.max(k + mem))
    pe = float(np.sum(k)) / (elapsed * m)                     # eq. (9)
    lb = float(np.sum(k)) / (m * max_k) if max_k > 0 else 0.0  # eq. (10)
    ce = max_k / max_km if max_km > 0 else 0.0                 # eq. (11)
    oe = max_km / elapsed                                      # eq. (12)
    return DeviceMetrics(
        parallel_efficiency=pe,
        load_balance=lb,
        communication_efficiency=ce,
        orchestration_efficiency=oe,
        elapsed=float(elapsed),
        n_devices=m,
        computational_efficiency=computational_efficiency,
    )
