"""ASCII trace rendering — the Paraver-style visual check.

The paper validates every metric against an execution trace ("the traces
serve as a visual confirmation that the reported metrics are consistent
with the observed behavior"). This renderer draws a ``Trace`` as one
timeline row per host rank and per device, with the paper's color
legend mapped to characters:

  host:   '#' useful (blue)   'o' offload (orange)   'm' MPI (red)
  device: '#' kernel (blue)   '=' memory (green)     '.' idle (gray)

Host rows are rendered from state *durations* in recorded order when the
trace was built synthetically (cursor order is chronological); device
rows are exact (records carry timestamps).
"""

from __future__ import annotations

from typing import List

import numpy as np

from . import intervals as ivx
from .states import DeviceState, Trace

__all__ = ["render_trace"]


def _paint(row: np.ndarray, intervals, ch: str, t0: float, scale: float):
    for s, e in intervals:
        a = int(round((s - t0) * scale))
        b = max(a + 1, int(round((e - t0) * scale)))
        row[a: min(b, len(row))] = ch


def render_trace(trace: Trace, width: int = 72, legend: bool = True) -> str:
    """Render one row per host rank and per device; ``legend=False``
    drops the state-character key from the header (embedding in logs
    that print it once)."""
    if trace.window is not None:
        t0, t1 = trace.window
    else:
        t1 = trace.elapsed
        t0 = 0.0
    span = t1 - t0
    # A degenerate (zero-width) window renders empty rows rather than
    # scaling finite durations by an effectively infinite factor.
    scale = width / span if span > 1e-12 else 0.0
    header = f"trace '{trace.name}'  [{t0:.3f}s .. {t1:.3f}s]"
    if legend:
        header += (
            "  (host: #=useful o=offload m=mpi"
            " | device: #=kernel ==memory .=idle)"
        )
    lines: List[str] = [header]
    # Host rows: reconstruct order-free proportional bars (durations only)
    for rank in sorted(trace.hosts):
        h = trace.hosts[rank]
        row = np.full(width, " ", dtype="<U1")
        cursor = 0
        for dur, ch in ((h.useful, "#"), (h.offload, "o"), (h.mpi, "m")):
            # Clamp to the remaining row: state totals can exceed the
            # window (or the window can be zero-width) without the
            # cursor running past the bar.
            n = min(int(round(dur * scale)), width - cursor)
            if n > 0:
                row[cursor: cursor + n] = ch
                cursor += n
        lines.append(f"rank {rank:3d} |{''.join(row)}|")
    # Device rows: exact interval painting
    for dev in sorted(trace.devices):
        tl = trace.devices[dev]
        states = tl.state_intervals((t0, t1))
        row = np.full(width, ".", dtype="<U1")
        if scale > 0:
            _paint(row, states[DeviceState.MEMORY], "=", t0, scale)
            _paint(row, states[DeviceState.KERNEL], "#", t0, scale)
        lines.append(f"dev  {dev:3d} |{''.join(row)}|")
    return "\n".join(lines)
