"""Columnar device-record storage — the zero-object ingestion core.

The paper's "lightweight monitoring" claim (§3.2, §4.2) only survives
CUPTI-scale activity streams if the record path does *not* allocate one
Python object per event. Production monitoring systems keep per-event
data in compact arrays (MPCDF's job monitor, arXiv:1909.11704; CERN's
heterogeneous-workload profiler streams batched activity buffers,
arXiv:2511.13928); this module is that discipline for TALP-JAX:

  * one activity record is one row of a NumPy **structured array** with
    layout ``kind:u1, start:f8, end:f8, stream:u4`` (21 bytes packed,
    vs ~200+ bytes for a ``DeviceRecord`` dataclass instance);
  * :class:`ColumnStore` is a preallocated append buffer with an
    amortized-doubling growth policy — scalar ``append`` for the legacy
    object façade, ``extend_columns`` for whole activity buffers;
  * kind codes are plain integers so per-kind selection during
    compaction is a vectorized boolean mask, not a Python comprehension.

:class:`~repro.core.states.DeviceTimeline` builds on this store;
backends deliver whole buffers through ``flush_arrays()`` (see
:mod:`repro.core.backends.base`) so records never materialize as
objects anywhere on the hot path.
"""

from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

__all__ = [
    "RECORD_DTYPE",
    "KIND_KERNEL",
    "KIND_MEMORY",
    "ColumnStore",
    "as_record_columns",
]

#: Packed per-record layout (≙ one CUPTI activity record).
RECORD_DTYPE = np.dtype(
    [("kind", "u1"), ("start", "f8"), ("end", "f8"), ("stream", "u4")]
)

# Integer kind codes (array-friendly stand-ins for DeviceActivity).
KIND_KERNEL = 0
KIND_MEMORY = 1


class ColumnStore:
    """Preallocated structured-array append buffer (amortized doubling).

    Rows live in a single contiguous ``RECORD_DTYPE`` array; ``append``
    writes one row, ``extend_columns`` writes a whole batch with four
    column assignments. ``view()`` exposes the filled prefix without a
    copy — callers must treat it as read-only and must not hold it
    across a ``clear()``/``append`` (the buffer may be reallocated).
    """

    __slots__ = ("_buf", "_n")

    def __init__(self, capacity: int = 1024):
        self._buf = np.empty(max(int(capacity), 16), dtype=RECORD_DTYPE)
        self._n = 0

    def __len__(self) -> int:
        return self._n

    @property
    def capacity(self) -> int:
        return len(self._buf)

    def _grow(self, need: int) -> None:
        cap = len(self._buf)
        while cap < need:
            cap *= 2
        new = np.empty(cap, dtype=RECORD_DTYPE)
        new[: self._n] = self._buf[: self._n]
        self._buf = new

    def append(self, kind: int, start: float, end: float, stream: int = 0) -> None:
        if self._n >= len(self._buf):
            self._grow(self._n + 1)
        self._buf[self._n] = (kind, start, end, stream)
        self._n += 1

    def extend_columns(
        self,
        kinds: np.ndarray,
        starts: np.ndarray,
        ends: np.ndarray,
        streams: Optional[np.ndarray] = None,
    ) -> int:
        """Bulk-append one batch of columns; returns rows written."""
        m = len(starts)
        if m == 0:
            return 0
        need = self._n + m
        if need > len(self._buf):
            self._grow(need)
        rows = self._buf[self._n:need]
        rows["kind"] = kinds
        rows["start"] = starts
        rows["end"] = ends
        rows["stream"] = 0 if streams is None else streams
        self._n = need
        return m

    def view(self) -> np.ndarray:
        """Read-only view of the filled prefix (no copy)."""
        return self._buf[: self._n]

    def take(self) -> np.ndarray:
        """Copy out the filled rows and clear the store."""
        out = self._buf[: self._n].copy()
        self._n = 0
        return out

    def clear(self) -> None:
        self._n = 0


def as_record_columns(
    kinds,
    starts,
    ends,
    streams=None,
    n: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Coerce and validate one activity-buffer batch to canonical columns.

    ``kinds`` may be an integer array, a scalar kind code applied to the
    whole batch, or a sequence of ``DeviceActivity`` members (converted
    via their ``code``). ``streams=None`` becomes a zero column. Raises
    ``ValueError`` on length mismatch or any ``end < start``.
    """
    starts = np.asarray(starts, dtype=np.float64).ravel()
    ends = np.asarray(ends, dtype=np.float64).ravel()
    m = len(starts) if n is None else n
    if len(starts) != m or len(ends) != m:
        raise ValueError(
            f"column length mismatch: starts={len(starts)} ends={len(ends)}"
        )
    if np.any(ends < starts):
        raise ValueError("record end < start in batch")
    if np.ndim(kinds) == 0 and not isinstance(kinds, np.ndarray):
        code = getattr(kinds, "code", kinds)
        kind_col = np.full(m, int(code), dtype=np.uint8)
    else:
        seq = [getattr(k, "code", k) for k in kinds] if not isinstance(
            kinds, np.ndarray
        ) else kinds
        kind_col = np.asarray(seq, dtype=np.uint8).ravel()
        if len(kind_col) != m:
            raise ValueError(
                f"column length mismatch: kinds={len(kind_col)} starts={m}"
            )
    if streams is None:
        stream_col = np.zeros(m, dtype=np.uint32)
    else:
        stream_col = np.asarray(streams, dtype=np.uint32).ravel()
        if len(stream_col) != m:
            raise ValueError(
                f"column length mismatch: streams={len(stream_col)} starts={m}"
            )
    return kind_col, starts, ends, stream_col
