"""repro.core — TALP efficiency metrics for accelerated systems (the paper).

Public API:
  * interval algebra: :mod:`repro.core.intervals`
  * state model: :mod:`repro.core.states`
  * metric engine: :mod:`repro.core.hierarchy` (``StateDurations``,
    ``MetricSpec``, ``Hierarchy``, the ``POP``/``HOST``/``DEVICE``/
    ``SCALABILITY`` instances)
  * metrics façades: :func:`pop_metrics`, :func:`host_metrics`,
    :func:`device_metrics`
  * hierarchy trees: :mod:`repro.core.tree`
  * monitor: :class:`TalpMonitor`
  * analysis/report: :func:`analyze_trace`, :mod:`repro.core.report`
  * backends: synthetic / runtime / analytical plugins
  * observability: :mod:`repro.core.telemetry` (Chrome/Perfetto trace
    export, JSONL/Prometheus metric stream, self-overhead accounting)
"""

from . import intervals
from . import telemetry
from .analysis import TraceAnalysis, analyze_trace
from .device_metrics import DeviceMetrics, device_metrics
from .hierarchy import (
    DEVICE,
    HOST,
    POP,
    SCALABILITY,
    Hierarchy,
    MetricFrame,
    MetricSpec,
    StateDurations,
)
from .host_metrics import HostMetrics, host_metrics
from .pop import PopMetrics, elapsed_time, pop_metrics
from .states import (
    DeviceActivity,
    DeviceOccupancy,
    DeviceRecord,
    DeviceState,
    DeviceTimeline,
    HostState,
    HostTimeline,
    Trace,
)
from .collect import FaultPlan, QuarantinedSpool, RankCoverage
from .merge import (
    AllGatherTransport,
    FileSpoolTransport,
    InProcessGather,
    merge_region_results,
    merge_results,
    merge_samples,
    merge_spool,
    talp_result_from_json,
)
from .talp import RegionResult, TalpMonitor, TalpResult
from .tree import MetricNode, device_tree, host_tree, tree_from_frame

__all__ = [
    "intervals",
    "telemetry",
    "TraceAnalysis",
    "analyze_trace",
    "DeviceMetrics",
    "device_metrics",
    "HostMetrics",
    "host_metrics",
    "PopMetrics",
    "elapsed_time",
    "pop_metrics",
    "StateDurations",
    "MetricSpec",
    "MetricFrame",
    "Hierarchy",
    "POP",
    "HOST",
    "DEVICE",
    "SCALABILITY",
    "DeviceActivity",
    "DeviceOccupancy",
    "DeviceRecord",
    "DeviceState",
    "DeviceTimeline",
    "HostState",
    "HostTimeline",
    "Trace",
    "RegionResult",
    "TalpMonitor",
    "TalpResult",
    "AllGatherTransport",
    "FaultPlan",
    "FileSpoolTransport",
    "InProcessGather",
    "QuarantinedSpool",
    "RankCoverage",
    "merge_region_results",
    "merge_results",
    "merge_samples",
    "merge_spool",
    "talp_result_from_json",
    "MetricNode",
    "device_tree",
    "host_tree",
    "tree_from_frame",
]
