"""repro.core — TALP efficiency metrics for accelerated systems (the paper).

Public API:
  * interval algebra: :mod:`repro.core.intervals`
  * state model: :mod:`repro.core.states`
  * metrics: :func:`pop_metrics`, :func:`host_metrics`, :func:`device_metrics`
  * hierarchy: :mod:`repro.core.tree`
  * monitor: :class:`TalpMonitor`
  * analysis/report: :func:`analyze_trace`, :mod:`repro.core.report`
  * backends: synthetic / runtime / analytical plugins
"""

from . import intervals
from .analysis import TraceAnalysis, analyze_trace
from .device_metrics import DeviceMetrics, device_metrics
from .host_metrics import HostMetrics, host_metrics
from .pop import PopMetrics, elapsed_time, pop_metrics
from .states import (
    DeviceActivity,
    DeviceOccupancy,
    DeviceRecord,
    DeviceState,
    DeviceTimeline,
    HostState,
    HostTimeline,
    Trace,
)
from .merge import (
    AllGatherTransport,
    FileSpoolTransport,
    InProcessGather,
    merge_region_results,
    merge_results,
    merge_spool,
    talp_result_from_json,
)
from .talp import RegionResult, TalpMonitor, TalpResult
from .tree import MetricNode, device_tree, host_tree

__all__ = [
    "intervals",
    "TraceAnalysis",
    "analyze_trace",
    "DeviceMetrics",
    "device_metrics",
    "HostMetrics",
    "host_metrics",
    "PopMetrics",
    "elapsed_time",
    "pop_metrics",
    "DeviceActivity",
    "DeviceOccupancy",
    "DeviceRecord",
    "DeviceState",
    "DeviceTimeline",
    "HostState",
    "HostTimeline",
    "Trace",
    "RegionResult",
    "TalpMonitor",
    "TalpResult",
    "AllGatherTransport",
    "FileSpoolTransport",
    "InProcessGather",
    "merge_region_results",
    "merge_results",
    "merge_spool",
    "talp_result_from_json",
    "MetricNode",
    "device_tree",
    "host_tree",
]
