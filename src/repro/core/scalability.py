"""POP Scalability metrics across multiple TALP runs (beyond-paper).

The paper computes only the *efficiency* branch ("Because TALP reports
the metrics for a single run, only the efficiency metrics can be
obtained. However, with the hardware counters collected by TALP, a user
can compute the scalability metrics of several TALP runs."). This module
is that computation: given per-run TALP results (or their JSON), it
derives the POP scaling branch relative to a baseline run:

    Speedup(n)                   = T_base / T_n
    Global Efficiency(n)         = Speedup / (resources_n / resources_base)
    Parallel Efficiency(n)       = from the run itself (eqs. 3/6)
    Computational Scalability(n) = Global Eff. / Parallel Eff.
                                   (= useful-computation growth: how much
                                   total useful work inflated with scale)

so Global = Computational Scalability × Parallel Efficiency, preserving
POP's multiplicative structure across the scan. The formulas live in
:data:`repro.core.hierarchy.SCALABILITY`; this module feeds it one
:class:`StateDurations` per run (baseline quantities via ``extras``).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Union

from .analysis import TraceAnalysis
from .hierarchy import SCALABILITY, StateDurations
from .talp import RegionResult

Result = Union[RegionResult, TraceAnalysis]

__all__ = ["ScalabilityPoint", "scalability_scan", "render_scalability"]


@dataclass(frozen=True)
class ScalabilityPoint:
    label: str
    resources: int            # ranks (or ranks × devices) in the run
    elapsed: float
    parallel_efficiency: float
    speedup: float
    global_efficiency: float
    computational_scalability: float

    def validate(self, tol: float = 1e-6) -> None:
        try:
            SCALABILITY.frame_of(self).validate(tol)
        except AssertionError:
            prod = self.computational_scalability * self.parallel_efficiency
            raise AssertionError(
                f"{self.label}: GE {self.global_efficiency} != "
                f"CS*PE {prod}"
            )


def _resources(r: Result) -> int:
    return max(1, len(r.host_states) or getattr(r, "n_ranks", 1))


def _pe(r: Result) -> float:
    if r.host is not None:
        return r.host.parallel_efficiency
    if r.device is not None:
        return r.device.parallel_efficiency
    raise ValueError("result carries no metrics")


def scalability_scan(
    results: Sequence[Result],
    labels: Optional[Sequence[str]] = None,
    resources: Optional[Sequence[int]] = None,
) -> List[ScalabilityPoint]:
    """First entry is the baseline. ``resources`` overrides rank counts
    (e.g. ranks × GPUs)."""
    if not results:
        return []
    labels = list(labels or [str(i) for i in range(len(results))])
    res = list(resources or [_resources(r) for r in results])
    base_t = results[0].elapsed
    base_r = res[0]
    points = []
    for r, lab, n in zip(results, labels, res):
        frame = SCALABILITY.compute(
            StateDurations(
                elapsed=r.elapsed,
                extras={
                    "base_elapsed": base_t,
                    "resources": float(n),
                    "base_resources": float(base_r),
                    "parallel_efficiency": _pe(r),
                },
            )
        )
        points.append(
            ScalabilityPoint(
                label=lab, resources=n, elapsed=r.elapsed,
                parallel_efficiency=frame["parallel_efficiency"],
                speedup=frame["speedup"],
                global_efficiency=frame["global_efficiency"],
                computational_scalability=frame["computational_scalability"],
            )
        )
    return points


def render_scalability(points: Sequence[ScalabilityPoint],
                       title: str = "POP scalability scan") -> str:
    lines = [title, f"{'run':>10s} {'res':>5s} {'elapsed':>10s} {'speedup':>8s} "
             f"{'GlobalEff':>10s} {'ParEff':>8s} {'CompScal':>9s}"]
    for p in points:
        lines.append(
            f"{p.label:>10s} {p.resources:5d} {p.elapsed:10.4f} "
            f"{p.speedup:8.3f} {p.global_efficiency:10.3f} "
            f"{p.parallel_efficiency:8.3f} {p.computational_scalability:9.3f}"
        )
    return "\n".join(lines)
