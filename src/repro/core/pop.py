"""Original POP MPI efficiency metrics (paper §3.3, eqs. 1–5).

Two-state model per MPI process: *Useful* computation vs *Not useful*
(stalled, e.g. in MPI). The metrics form a multiplicative hierarchy:

    Parallel Efficiency = Load Balance × Communication Efficiency

The formulas themselves live in :data:`repro.core.hierarchy.POP` — this
module is a thin façade that validates inputs and exposes the classic
``PopMetrics`` dataclass.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .hierarchy import POP, MetricFrame, StateDurations, elapsed_time

__all__ = ["PopMetrics", "pop_metrics", "elapsed_time"]


@dataclass(frozen=True)
class PopMetrics:
    parallel_efficiency: float
    load_balance: float
    communication_efficiency: float
    elapsed: float
    n_processes: int

    @classmethod
    def from_frame(cls, frame: MetricFrame) -> "PopMetrics":
        return cls(**frame.scalar_fields())

    def frame(self) -> MetricFrame:
        return POP.frame_of(self)

    def validate(self, tol: float = 1e-9) -> None:
        """Parent = product of children (multiplicative hierarchy)."""
        self.frame().validate(tol)


def pop_metrics(
    useful: Sequence[float],
    not_useful: Optional[Sequence[float]] = None,
    elapsed: Optional[float] = None,
) -> PopMetrics:
    """Compute eqs. (3)–(5). Provide either per-process not_useful or E."""
    u = np.asarray(useful, dtype=np.float64)
    if u.ndim != 1 or len(u) == 0:
        raise ValueError("useful must be 1-D, non-empty")
    if np.any(u < 0):
        raise ValueError("negative useful time")
    if elapsed is None:
        if not_useful is None:
            raise ValueError("need not_useful or elapsed")
        elapsed = elapsed_time(u, not_useful)
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    sd = StateDurations(elapsed=float(elapsed), useful=u)
    return PopMetrics.from_frame(POP.compute(sd))
