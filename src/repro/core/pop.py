"""Original POP MPI efficiency metrics (paper §3.3, eqs. 1–5).

Two-state model per MPI process: *Useful* computation vs *Not useful*
(stalled, e.g. in MPI). The metrics form a multiplicative hierarchy:

    Parallel Efficiency = Load Balance × Communication Efficiency
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

__all__ = ["PopMetrics", "pop_metrics", "elapsed_time"]


def elapsed_time(useful: Sequence[float], not_useful: Sequence[float]) -> float:
    """Eq. (1): E = max_i (D_U_i + D_notU_i)."""
    u = np.asarray(useful, dtype=np.float64)
    nu = np.asarray(not_useful, dtype=np.float64)
    if u.shape != nu.shape or u.ndim != 1 or len(u) == 0:
        raise ValueError("useful/not_useful must be equal-length 1-D, non-empty")
    return float(np.max(u + nu))


@dataclass(frozen=True)
class PopMetrics:
    parallel_efficiency: float
    load_balance: float
    communication_efficiency: float
    elapsed: float
    n_processes: int

    def validate(self, tol: float = 1e-9) -> None:
        """Parent = product of children (multiplicative hierarchy)."""
        prod = self.load_balance * self.communication_efficiency
        if abs(prod - self.parallel_efficiency) > tol:
            raise AssertionError(
                f"PE {self.parallel_efficiency} != LB*CE {prod}"
            )


def pop_metrics(
    useful: Sequence[float],
    not_useful: Optional[Sequence[float]] = None,
    elapsed: Optional[float] = None,
) -> PopMetrics:
    """Compute eqs. (3)–(5). Provide either per-process not_useful or E."""
    u = np.asarray(useful, dtype=np.float64)
    if u.ndim != 1 or len(u) == 0:
        raise ValueError("useful must be 1-D, non-empty")
    if np.any(u < 0):
        raise ValueError("negative useful time")
    n = len(u)
    if elapsed is None:
        if not_useful is None:
            raise ValueError("need not_useful or elapsed")
        elapsed = elapsed_time(u, not_useful)
    if elapsed <= 0:
        raise ValueError("elapsed must be positive")
    max_u = float(np.max(u))
    pe = float(np.sum(u)) / (elapsed * n)                      # eq. (3)
    lb = float(np.sum(u)) / (n * max_u) if max_u > 0 else 0.0  # eq. (4)
    ce = max_u / elapsed                                       # eq. (5)
    return PopMetrics(
        parallel_efficiency=pe,
        load_balance=lb,
        communication_efficiency=ce,
        elapsed=float(elapsed),
        n_processes=n,
    )
