"""Execution-state model for TALP on accelerated platforms.

The paper's simplified execution model (§4.1):

  * Host (per MPI process/rank): three mutually exclusive states —
    (i) ``USEFUL`` computation, (ii) ``OFFLOAD`` (blocked in
    device-related operations: transfers, launches, synchronization),
    (iii) ``MPI`` (blocked in cross-process communication).
  * Device (per accelerator, streams not distinguished): three states —
    (i) ``KERNEL`` computation (useful work), (ii) ``MEMORY`` operations,
    (iii) ``IDLE``. Overlap between computation and communication
    streams counts as computation.

``HostTimeline`` holds per-state accumulated durations for one rank.
``DeviceTimeline`` holds raw activity records for one device and applies
the paper's flattening pipeline to produce the state occupancy breakdown.
``Trace`` aggregates both sides for one monitored region/run.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import intervals as iv


class HostState(enum.Enum):
    USEFUL = "useful"
    OFFLOAD = "offload"  # "Device Offloading" in the paper
    MPI = "mpi"


class DeviceActivity(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"


class DeviceState(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"
    IDLE = "idle"


@dataclass
class DeviceRecord:
    """One raw activity record, as delivered by a backend (≙ CUPTI activity)."""

    kind: DeviceActivity
    start: float
    end: float
    stream: int = 0
    name: str = ""

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError(f"record end < start: {self}")


@dataclass
class HostTimeline:
    """Accumulated host-state durations for one rank.

    ``useful`` may either be accumulated explicitly or derived as
    ``elapsed - offload - mpi`` (the TALP measurement model: PMPI
    intercepts MPI time, the CUPTI-analogue intercepts offload time,
    everything else is useful).
    """

    rank: int = 0
    useful: float = 0.0
    offload: float = 0.0
    mpi: float = 0.0

    def add(self, state: HostState, duration: float) -> None:
        if duration < 0:
            raise ValueError("negative duration")
        if state is HostState.USEFUL:
            self.useful += duration
        elif state is HostState.OFFLOAD:
            self.offload += duration
        else:
            self.mpi += duration

    @property
    def elapsed(self) -> float:
        return self.useful + self.offload + self.mpi

    def as_dict(self) -> Dict[str, float]:
        return {"useful": self.useful, "offload": self.offload, "mpi": self.mpi}


@dataclass
class DeviceOccupancy:
    """Flattened per-device state breakdown over a window."""

    kernel: float
    memory: float
    idle: float

    @property
    def elapsed(self) -> float:
        return self.kernel + self.memory + self.idle

    def as_dict(self) -> Dict[str, float]:
        return {"kernel": self.kernel, "memory": self.memory, "idle": self.idle}


@dataclass
class DeviceTimeline:
    """Raw activity records for one device + the paper's post-processing.

    The pipeline (§4.2, backend-independent):
      1. kernel records are flattened across streams,
      2. memory records are flattened, then kernel-overlapping segments
         are removed (overlap counts as computation),
      3. remaining uncovered window time is idle.
    """

    device: int = 0
    records: List[DeviceRecord] = field(default_factory=list)

    def add(self, kind: DeviceActivity, start: float, end: float,
            stream: int = 0, name: str = "") -> None:
        self.records.append(DeviceRecord(kind, start, end, stream, name))

    def extend(self, records: Iterable[DeviceRecord]) -> None:
        self.records.extend(records)

    def _raw(self, kind: DeviceActivity) -> np.ndarray:
        pairs = [(r.start, r.end) for r in self.records if r.kind is kind]
        return iv.as_intervals(pairs) if pairs else iv.EMPTY.copy()

    def occupancy(self, window: Optional[Tuple[float, float]] = None) -> DeviceOccupancy:
        kern = iv.flatten(self._raw(DeviceActivity.KERNEL))
        mem = iv.subtract(iv.flatten(self._raw(DeviceActivity.MEMORY)), kern)
        if window is None:
            lo = min((r.start for r in self.records), default=0.0)
            hi = max((r.end for r in self.records), default=0.0)
            window = (lo, hi)
        kern_c = iv.clip(kern, *window)
        mem_c = iv.clip(mem, *window)
        idle = iv.subtract(iv.gaps(iv.union(kern_c, mem_c), *window), iv.EMPTY)
        return DeviceOccupancy(
            kernel=iv.total(kern_c), memory=iv.total(mem_c), idle=iv.total(idle)
        )

    def state_intervals(self, window: Tuple[float, float]) -> Dict[DeviceState, np.ndarray]:
        """Disjoint per-state intervals over a window (for trace rendering)."""
        kern = iv.clip(iv.flatten(self._raw(DeviceActivity.KERNEL)), *window)
        mem = iv.clip(
            iv.subtract(iv.flatten(self._raw(DeviceActivity.MEMORY)), kern), *window
        )
        idle = iv.gaps(iv.union(kern, mem), *window)
        return {DeviceState.KERNEL: kern, DeviceState.MEMORY: mem, DeviceState.IDLE: idle}


@dataclass
class Trace:
    """One monitored region: host timelines per rank + device timelines.

    ``elapsed`` follows paper eq. (1): E = max_i (D_useful_i + D_not_useful_i)
    unless an explicit window is provided (then E = window span, which is
    what the online runtime backend uses).
    """

    hosts: Dict[int, HostTimeline] = field(default_factory=dict)
    devices: Dict[int, DeviceTimeline] = field(default_factory=dict)
    window: Optional[Tuple[float, float]] = None
    name: str = "Global"

    def host(self, rank: int) -> HostTimeline:
        if rank not in self.hosts:
            self.hosts[rank] = HostTimeline(rank=rank)
        return self.hosts[rank]

    def device(self, dev: int) -> DeviceTimeline:
        if dev not in self.devices:
            self.devices[dev] = DeviceTimeline(device=dev)
        return self.devices[dev]

    @property
    def elapsed(self) -> float:
        if self.window is not None:
            return self.window[1] - self.window[0]
        if not self.hosts:
            # device-only trace: use the union span of device activity
            spans = [
                d.occupancy().elapsed for d in self.devices.values()
            ]
            return max(spans, default=0.0)
        return max(h.elapsed for h in self.hosts.values())

    def device_occupancies(self) -> Dict[int, DeviceOccupancy]:
        win = self.window
        if win is None and self.hosts:
            win = (0.0, self.elapsed)
        return {d: tl.occupancy(win) for d, tl in self.devices.items()}
