"""Execution-state model for TALP on accelerated platforms.

The paper's simplified execution model (§4.1):

  * Host (per MPI process/rank): three mutually exclusive states —
    (i) ``USEFUL`` computation, (ii) ``OFFLOAD`` (blocked in
    device-related operations: transfers, launches, synchronization),
    (iii) ``MPI`` (blocked in cross-process communication).
  * Device (per accelerator, streams not distinguished): three states —
    (i) ``KERNEL`` computation (useful work), (ii) ``MEMORY`` operations,
    (iii) ``IDLE``. Overlap between computation and communication
    streams counts as computation.

``HostTimeline`` holds per-state accumulated durations for one rank.
``DeviceTimeline`` holds raw activity records for one device and applies
the paper's flattening pipeline to produce the state occupancy breakdown.
``Trace`` aggregates both sides for one monitored region/run.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import intervals as iv
from .recordio import KIND_KERNEL, KIND_MEMORY, ColumnStore, as_record_columns
from .telemetry import overhead as _ovh


class HostState(enum.Enum):
    USEFUL = "useful"
    OFFLOAD = "offload"  # "Device Offloading" in the paper
    MPI = "mpi"


class DeviceActivity(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"

    @property
    def code(self) -> int:
        """Integer kind code used by the columnar record engine."""
        return KIND_KERNEL if self is DeviceActivity.KERNEL else KIND_MEMORY

    @classmethod
    def from_code(cls, code: int) -> "DeviceActivity":
        return _KIND_BY_CODE[int(code)]


_KIND_BY_CODE = (DeviceActivity.KERNEL, DeviceActivity.MEMORY)


class DeviceState(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"
    IDLE = "idle"


@dataclass
class DeviceRecord:
    """One raw activity record, as delivered by a backend (≙ CUPTI activity)."""

    kind: DeviceActivity
    start: float
    end: float
    stream: int = 0
    name: str = ""

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError(f"record end < start: {self}")


@dataclass
class HostTimeline:
    """Accumulated host-state durations for one rank.

    ``useful`` may either be accumulated explicitly or derived as
    ``elapsed - offload - mpi`` (the TALP measurement model: PMPI
    intercepts MPI time, the CUPTI-analogue intercepts offload time,
    everything else is useful).
    """

    rank: int = 0
    useful: float = 0.0
    offload: float = 0.0
    mpi: float = 0.0

    def add(self, state: HostState, duration: float) -> None:
        if duration < 0:
            raise ValueError("negative duration")
        if state is HostState.USEFUL:
            self.useful += duration
        elif state is HostState.OFFLOAD:
            self.offload += duration
        else:
            self.mpi += duration

    @property
    def elapsed(self) -> float:
        return self.useful + self.offload + self.mpi

    def as_dict(self) -> Dict[str, float]:
        return {"useful": self.useful, "offload": self.offload, "mpi": self.mpi}


@dataclass
class DeviceOccupancy:
    """Flattened per-device state breakdown over a window."""

    kernel: float
    memory: float
    idle: float

    @property
    def elapsed(self) -> float:
        return self.kernel + self.memory + self.idle

    def as_dict(self) -> Dict[str, float]:
        return {"kernel": self.kernel, "memory": self.memory, "idle": self.idle}


@dataclass
class DeviceTimeline:
    """Activity records for one device + the paper's post-processing.

    The pipeline (§4.2, backend-independent):
      1. kernel records are flattened across streams,
      2. memory records are flattened, then kernel-overlapping segments
         are removed (overlap counts as computation),
      3. remaining uncovered window time is idle.

    Storage is **columnar and zero-object**: pending records live in a
    preallocated NumPy structured buffer (``kind:u1, start:f8, end:f8,
    stream:u4``, amortized-doubling growth — see
    :class:`repro.core.recordio.ColumnStore`); no ``DeviceRecord``
    instance is ever allocated on the ingestion path. Backends deliver
    whole activity buffers through :meth:`ingest_arrays`; the per-record
    ``add()``/``ingest()`` methods are a thin compatibility façade over
    the same store, and :attr:`records` materializes the pending rows as
    ``DeviceRecord`` objects only on demand (tests, debugging).

    Ingestion is *streaming*: pending rows accumulate until
    ``compact_threshold`` is reached, then they are folded into per-kind
    flattened interval arrays (``compact()`` — a vectorized
    boolean-mask-per-kind fold, no Python loop over records). A timeline
    therefore holds at most ``compact_threshold`` pending rows plus the
    (disjoint, hence bounded by trace structure, not record count)
    compacted arrays — a million activity records flatten in bounded
    memory. Compaction is lossy w.r.t. per-record identity (stream ids,
    kernel names) but exact w.r.t. the state occupancy the metrics are
    computed from.
    """

    device: int = 0
    compact_threshold: int = 65536
    _store: ColumnStore = field(init=False, repr=False)
    _compact: Dict[DeviceActivity, np.ndarray] = field(
        default_factory=dict, init=False, repr=False
    )
    _span: Optional[Tuple[float, float]] = field(default=None, init=False, repr=False)
    _n_compacted: int = field(default=0, init=False, repr=False)
    # Kernel-record launch count, maintained at ingest time (compaction
    # folds records into flattened occupancy, losing per-record identity,
    # so the count cannot be recovered later). Feeds the monitor's
    # measured Computational Efficiency (launches × model FLOPs).
    _n_kernel: int = field(default=0, init=False, repr=False)
    # kind -> (pending-count watermark, flattened intervals); pending count
    # only moves monotonically between compactions (which clear the cache),
    # so it is a sound cache key.
    _kind_cache: Dict[DeviceActivity, Tuple[int, np.ndarray]] = field(
        default_factory=dict, init=False, repr=False
    )
    # kind -> capacity slab backing the streaming-append fast path of
    # compact(); the logical array in ``_compact`` is a prefix view of
    # it. Writes only ever touch rows past every outstanding view's
    # length, so shared views stay valid.
    _compact_buf: Dict[DeviceActivity, np.ndarray] = field(
        default_factory=dict, init=False, repr=False
    )

    def __post_init__(self):
        if self.compact_threshold <= 0:
            raise ValueError(
                f"compact_threshold must be positive, got {self.compact_threshold}"
            )
        self._store = ColumnStore(capacity=min(self.compact_threshold, 4096))

    @property
    def n_records(self) -> int:
        """Total records ever ingested (pending + already compacted)."""
        return self._n_compacted + len(self._store)

    @property
    def n_pending(self) -> int:
        """Pending (not yet compacted) records currently buffered."""
        return len(self._store)

    @property
    def n_kernel_records(self) -> int:
        """Kernel records ever ingested (a launch count — counted at
        ingest time, since compaction erases per-record identity)."""
        return self._n_kernel

    @property
    def records(self) -> List[DeviceRecord]:
        """Pending rows materialized as ``DeviceRecord`` objects.

        Compatibility façade over the columnar store — a fresh list is
        built per access (names are not retained by the columnar core),
        so mutating it does not affect the timeline.
        """
        v = self._store.view()
        return [
            DeviceRecord(_KIND_BY_CODE[k], float(s), float(e), int(st))
            for k, s, e, st in zip(v["kind"], v["start"], v["end"], v["stream"])
        ]

    def add(self, kind: DeviceActivity, start: float, end: float,
            stream: int = 0, name: str = "") -> None:
        if end < start:
            raise ValueError(
                f"record end < start: ({kind}, {start}, {end})"
            )
        self._store.append(kind.code, start, end, stream)
        if kind is DeviceActivity.KERNEL:
            self._n_kernel += 1
        if len(self._store) >= self.compact_threshold:
            self.compact()

    def extend(self, records: Iterable[DeviceRecord]) -> None:
        self.ingest(records)

    def ingest(self, records: Iterable, chunk_size: Optional[int] = None) -> int:
        """Stream records (``DeviceRecord`` or ``(kind, start, end[, stream,
        name])`` tuples) from any iterable, compacting every ``chunk_size``
        records so arbitrarily long streams are ingested in bounded memory.
        Returns the number of records ingested."""
        if chunk_size is not None and chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        chunk = self.compact_threshold if chunk_size is None else chunk_size
        store = self._store
        n = 0
        for rec in records:
            if isinstance(rec, DeviceRecord):
                kind, start, end, stream = rec.kind, rec.start, rec.end, rec.stream
            else:
                kind, start, end = rec[0], rec[1], rec[2]
                stream = rec[3] if len(rec) > 3 else 0
            if end < start:
                raise ValueError(f"record end < start: ({kind}, {start}, {end})")
            code = kind.code if isinstance(kind, DeviceActivity) else int(kind)
            store.append(code, start, end, stream)
            if code == KIND_KERNEL:
                self._n_kernel += 1
            n += 1
            if len(store) >= chunk:
                self.compact()
        return n

    def ingest_arrays(
        self,
        kinds,
        starts,
        ends,
        streams=None,
    ) -> int:
        """Batch API: ingest one whole activity buffer as columns.

        ``kinds`` is an integer kind-code array, a sequence of
        :class:`DeviceActivity`, or a single kind applied to the whole
        batch; ``starts``/``ends`` are float arrays; ``streams`` defaults
        to stream 0. The batch is appended in ``compact_threshold``-sized
        slices with compaction in between, so arbitrarily large buffers
        ingest in bounded memory. Returns the number of records ingested.
        """
        kind_col, starts, ends, stream_col = as_record_columns(
            kinds, starts, ends, streams
        )
        self._n_kernel += int(np.count_nonzero(kind_col == KIND_KERNEL))
        m = len(starts)
        pos = 0
        while pos < m:
            room = self.compact_threshold - len(self._store)
            if room <= 0:
                self.compact()
                continue
            end_pos = min(m, pos + room)
            self._store.extend_columns(
                kind_col[pos:end_pos], starts[pos:end_pos],
                ends[pos:end_pos], stream_col[pos:end_pos],
            )
            pos = end_pos
            if len(self._store) >= self.compact_threshold:
                self.compact()
        return m

    def compact(self) -> None:
        """Fold pending rows into the per-kind flattened arrays.

        Fully vectorized: per-kind selection is a boolean mask over the
        columnar buffer; the flatten itself is the vectorized merge in
        :func:`repro.core.intervals.flatten`.
        """
        v = self._store.view()
        if len(v) == 0:
            return
        with _ovh.section("compact"):
            starts, ends, kinds = v["start"], v["end"], v["kind"]
            lo, hi = float(starts.min()), float(ends.max())
            self._span = (
                (lo, hi) if self._span is None
                else (min(self._span[0], lo), max(self._span[1], hi))
            )
            for kind in DeviceActivity:
                mask = kinds == kind.code
                if not mask.any():
                    continue
                pairs = iv.flatten(np.stack([starts[mask], ends[mask]], axis=1))
                base = self._compact.get(kind)
                if base is None or len(base) == 0:
                    self._compact[kind] = pairs
                    self._compact_buf.pop(kind, None)
                elif len(pairs) and pairs[0, 0] > base[-1, 1]:
                    # Streaming fast path: the new chunk lies strictly
                    # after the compacted history (records arrive in time
                    # order), so the fold appends into a capacity-doubling
                    # slab — amortized O(chunk) per compact, not
                    # O(history). Appends land past the end of every
                    # outstanding prefix view, so sharing stays safe.
                    n, k = len(base), len(pairs)
                    buf = self._compact_buf.get(kind)
                    if buf is None or base.base is not buf or n + k > len(buf):
                        buf = np.empty((max(2 * (n + k), 1024), 2),
                                       dtype=np.float64)
                        buf[:n] = base
                        self._compact_buf[kind] = buf
                    buf[n:n + k] = pairs
                    self._compact[kind] = buf[: n + k]
                else:
                    self._compact[kind] = iv.flatten(
                        np.concatenate([base, pairs], axis=0)
                    )
                    self._compact_buf.pop(kind, None)
            self._n_compacted += len(v)
            self._store.clear()
            self._kind_cache.clear()

    def kind_intervals(self, kind: DeviceActivity) -> np.ndarray:
        """Flattened intervals of one activity kind (compacted + pending).

        Cached on the pending-row watermark, so repeated calls between
        ingests (the online ``sample()`` pattern) are O(1) instead of
        O(pending). Treat the returned array as read-only.
        """
        n_pending = len(self._store)
        cached = self._kind_cache.get(kind)
        if cached is not None and cached[0] == n_pending:
            return cached[1]
        v = self._store.view()
        mask = v["kind"] == kind.code
        base = self._compact.get(kind)
        if not mask.any():
            # No pending rows of this kind: hand out the compacted array
            # itself (read-only contract above). Compaction never mutates
            # it in place — folds reassign a fresh array — so sharing is
            # safe and the post-compact path stays O(1) per call.
            out = base if base is not None else iv.EMPTY.copy()
        else:
            pairs = np.stack([v["start"][mask], v["end"][mask]], axis=1)
            if base is not None:
                pairs = np.concatenate([base, pairs], axis=0)
            out = iv.flatten(pairs)
        self._kind_cache[kind] = (n_pending, out)
        return out

    def span(self) -> Tuple[float, float]:
        """(min start, max end) over every record ever ingested."""
        lo, hi = self._span if self._span is not None else (np.inf, -np.inf)
        v = self._store.view()
        if len(v):
            lo = min(lo, float(v["start"].min()))
            hi = max(hi, float(v["end"].max()))
        if lo > hi:
            return (0.0, 0.0)
        return (lo, hi)

    # -- columnar serialization (binary spool payloads) -----------------
    def to_columns(self) -> Dict[str, object]:
        """Columnar snapshot: pending structured rows + compacted per-kind
        interval arrays + metadata — the payload the binary spool format
        writes (NPZ columns, no per-record encoding)."""
        return {
            "pending": self._store.view().copy(),
            "kernel": self._compact.get(DeviceActivity.KERNEL, iv.EMPTY).copy(),
            "memory": self._compact.get(DeviceActivity.MEMORY, iv.EMPTY).copy(),
            "meta": {
                "device": self.device,
                "compact_threshold": self.compact_threshold,
                "n_compacted": self._n_compacted,
                "n_kernel": self._n_kernel,
                "span": list(self._span) if self._span is not None else None,
            },
        }

    @classmethod
    def from_columns(
        cls,
        pending: np.ndarray,
        kernel: np.ndarray,
        memory: np.ndarray,
        device: int = 0,
        compact_threshold: int = 65536,
        n_compacted: int = 0,
        span: Optional[Tuple[float, float]] = None,
        n_kernel: Optional[int] = None,
    ) -> "DeviceTimeline":
        """Inverse of :meth:`to_columns` (exact state reconstruction).

        ``n_kernel`` restores the launch count; payloads from producers
        that did not record it fall back to counting the pending rows
        (the compacted portion's launches are unrecoverable)."""
        tl = cls(device=device, compact_threshold=compact_threshold)
        if len(kernel):
            tl._compact[DeviceActivity.KERNEL] = iv.as_intervals(kernel)
        if len(memory):
            tl._compact[DeviceActivity.MEMORY] = iv.as_intervals(memory)
        tl._n_compacted = int(n_compacted)
        tl._span = (float(span[0]), float(span[1])) if span is not None else None
        if len(pending):
            tl._store.extend_columns(
                pending["kind"], pending["start"],
                pending["end"], pending["stream"],
            )
        tl._n_kernel = (
            int(n_kernel) if n_kernel is not None
            else int(np.count_nonzero(pending["kind"] == KIND_KERNEL))
            if len(pending) else 0
        )
        return tl

    def occupancy(self, window: Optional[Tuple[float, float]] = None) -> DeviceOccupancy:
        kern = self.kind_intervals(DeviceActivity.KERNEL)
        mem = iv.subtract(self.kind_intervals(DeviceActivity.MEMORY), kern)
        if window is None:
            window = self.span()
        kern_c = iv.clip(kern, *window)
        mem_c = iv.clip(mem, *window)
        idle = iv.gaps(iv.union(kern_c, mem_c), *window)
        return DeviceOccupancy(
            kernel=iv.total(kern_c), memory=iv.total(mem_c), idle=iv.total(idle)
        )

    def state_intervals(self, window: Tuple[float, float]) -> Dict[DeviceState, np.ndarray]:
        """Disjoint per-state intervals over a window (for trace rendering)."""
        kern = iv.clip(self.kind_intervals(DeviceActivity.KERNEL), *window)
        mem = iv.clip(
            iv.subtract(self.kind_intervals(DeviceActivity.MEMORY), kern), *window
        )
        idle = iv.gaps(iv.union(kern, mem), *window)
        return {DeviceState.KERNEL: kern, DeviceState.MEMORY: mem, DeviceState.IDLE: idle}


@dataclass
class ObjectPathTimeline:
    """Retained object-per-event reference implementation of
    :class:`DeviceTimeline` (one Python ``DeviceRecord`` per activity
    event, per-record list-comprehension compaction).

    Kept verbatim as the correctness oracle for the columnar engine: the
    hypothesis property tests and ``benchmarks/merge_bench.py`` assert
    bit-identical compacted intervals, spans and metric frames between
    this path and the columnar one — and the benchmark gates the
    columnar path's ≥10× ingestion+compaction speedup against it. Not
    used on any production path.
    """

    device: int = 0
    records: List[DeviceRecord] = field(default_factory=list)
    compact_threshold: int = 65536
    _compact: Dict[DeviceActivity, np.ndarray] = field(
        default_factory=dict, init=False, repr=False
    )
    _span: Optional[Tuple[float, float]] = field(default=None, init=False, repr=False)
    _n_compacted: int = field(default=0, init=False, repr=False)

    @property
    def n_records(self) -> int:
        return self._n_compacted + len(self.records)

    def add(self, kind: DeviceActivity, start: float, end: float,
            stream: int = 0, name: str = "") -> None:
        self.records.append(DeviceRecord(kind, start, end, stream, name))
        if len(self.records) >= self.compact_threshold:
            self.compact()

    def extend(self, records: Iterable[DeviceRecord]) -> None:
        self.ingest(records)

    def ingest(self, records: Iterable, chunk_size: Optional[int] = None) -> int:
        if chunk_size is not None and chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive, got {chunk_size}")
        chunk = self.compact_threshold if chunk_size is None else chunk_size
        n = 0
        for rec in records:
            if not isinstance(rec, DeviceRecord):
                rec = DeviceRecord(*rec)
            self.records.append(rec)
            n += 1
            if len(self.records) >= chunk:
                self.compact()
        return n

    def compact(self) -> None:
        if not self.records:
            return
        lo = min(r.start for r in self.records)
        hi = max(r.end for r in self.records)
        self._span = (
            (lo, hi) if self._span is None
            else (min(self._span[0], lo), max(self._span[1], hi))
        )
        for kind in DeviceActivity:
            pairs = [(r.start, r.end) for r in self.records if r.kind is kind]
            if not pairs:
                continue
            parts = [iv.as_intervals(pairs)]
            if kind in self._compact:
                parts.append(self._compact[kind])
            self._compact[kind] = iv.flatten(np.concatenate(parts, axis=0))
        self._n_compacted += len(self.records)
        self.records.clear()

    def kind_intervals(self, kind: DeviceActivity) -> np.ndarray:
        pairs = [(r.start, r.end) for r in self.records if r.kind is kind]
        base = self._compact.get(kind)
        if base is None:
            return iv.flatten(iv.as_intervals(pairs)) if pairs else iv.EMPTY.copy()
        if not pairs:
            return base.copy()
        return iv.flatten(np.concatenate([base, iv.as_intervals(pairs)], axis=0))

    def span(self) -> Tuple[float, float]:
        lo, hi = self._span if self._span is not None else (np.inf, -np.inf)
        for r in self.records:
            lo = min(lo, r.start)
            hi = max(hi, r.end)
        if lo > hi:
            return (0.0, 0.0)
        return (lo, hi)

    def occupancy(self, window: Optional[Tuple[float, float]] = None) -> DeviceOccupancy:
        kern = self.kind_intervals(DeviceActivity.KERNEL)
        mem = iv.subtract(self.kind_intervals(DeviceActivity.MEMORY), kern)
        if window is None:
            window = self.span()
        kern_c = iv.clip(kern, *window)
        mem_c = iv.clip(mem, *window)
        idle = iv.gaps(iv.union(kern_c, mem_c), *window)
        return DeviceOccupancy(
            kernel=iv.total(kern_c), memory=iv.total(mem_c), idle=iv.total(idle)
        )


@dataclass
class Trace:
    """One monitored region: host timelines per rank + device timelines.

    ``elapsed`` follows paper eq. (1): E = max_i (D_useful_i + D_not_useful_i)
    unless an explicit window is provided (then E = window span, which is
    what the online runtime backend uses).
    """

    hosts: Dict[int, HostTimeline] = field(default_factory=dict)
    devices: Dict[int, DeviceTimeline] = field(default_factory=dict)
    window: Optional[Tuple[float, float]] = None
    name: str = "Global"

    def host(self, rank: int) -> HostTimeline:
        if rank not in self.hosts:
            self.hosts[rank] = HostTimeline(rank=rank)
        return self.hosts[rank]

    def device(self, dev: int) -> DeviceTimeline:
        if dev not in self.devices:
            self.devices[dev] = DeviceTimeline(device=dev)
        return self.devices[dev]

    @property
    def elapsed(self) -> float:
        if self.window is not None:
            return self.window[1] - self.window[0]
        if not self.hosts:
            # device-only trace: use the union span of device activity
            spans = [
                d.occupancy().elapsed for d in self.devices.values()
            ]
            return max(spans, default=0.0)
        return max(h.elapsed for h in self.hosts.values())

    def device_occupancies(self) -> Dict[int, DeviceOccupancy]:
        win = self.window
        if win is None and self.hosts:
            win = (0.0, self.elapsed)
        return {d: tl.occupancy(win) for d, tl in self.devices.items()}
