"""Execution-state model for TALP on accelerated platforms.

The paper's simplified execution model (§4.1):

  * Host (per MPI process/rank): three mutually exclusive states —
    (i) ``USEFUL`` computation, (ii) ``OFFLOAD`` (blocked in
    device-related operations: transfers, launches, synchronization),
    (iii) ``MPI`` (blocked in cross-process communication).
  * Device (per accelerator, streams not distinguished): three states —
    (i) ``KERNEL`` computation (useful work), (ii) ``MEMORY`` operations,
    (iii) ``IDLE``. Overlap between computation and communication
    streams counts as computation.

``HostTimeline`` holds per-state accumulated durations for one rank.
``DeviceTimeline`` holds raw activity records for one device and applies
the paper's flattening pipeline to produce the state occupancy breakdown.
``Trace`` aggregates both sides for one monitored region/run.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from . import intervals as iv


class HostState(enum.Enum):
    USEFUL = "useful"
    OFFLOAD = "offload"  # "Device Offloading" in the paper
    MPI = "mpi"


class DeviceActivity(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"


class DeviceState(enum.Enum):
    KERNEL = "kernel"
    MEMORY = "memory"
    IDLE = "idle"


@dataclass
class DeviceRecord:
    """One raw activity record, as delivered by a backend (≙ CUPTI activity)."""

    kind: DeviceActivity
    start: float
    end: float
    stream: int = 0
    name: str = ""

    def __post_init__(self):
        if self.end < self.start:
            raise ValueError(f"record end < start: {self}")


@dataclass
class HostTimeline:
    """Accumulated host-state durations for one rank.

    ``useful`` may either be accumulated explicitly or derived as
    ``elapsed - offload - mpi`` (the TALP measurement model: PMPI
    intercepts MPI time, the CUPTI-analogue intercepts offload time,
    everything else is useful).
    """

    rank: int = 0
    useful: float = 0.0
    offload: float = 0.0
    mpi: float = 0.0

    def add(self, state: HostState, duration: float) -> None:
        if duration < 0:
            raise ValueError("negative duration")
        if state is HostState.USEFUL:
            self.useful += duration
        elif state is HostState.OFFLOAD:
            self.offload += duration
        else:
            self.mpi += duration

    @property
    def elapsed(self) -> float:
        return self.useful + self.offload + self.mpi

    def as_dict(self) -> Dict[str, float]:
        return {"useful": self.useful, "offload": self.offload, "mpi": self.mpi}


@dataclass
class DeviceOccupancy:
    """Flattened per-device state breakdown over a window."""

    kernel: float
    memory: float
    idle: float

    @property
    def elapsed(self) -> float:
        return self.kernel + self.memory + self.idle

    def as_dict(self) -> Dict[str, float]:
        return {"kernel": self.kernel, "memory": self.memory, "idle": self.idle}


@dataclass
class DeviceTimeline:
    """Activity records for one device + the paper's post-processing.

    The pipeline (§4.2, backend-independent):
      1. kernel records are flattened across streams,
      2. memory records are flattened, then kernel-overlapping segments
         are removed (overlap counts as computation),
      3. remaining uncovered window time is idle.

    Ingestion is *streaming*: raw records accumulate in ``records`` until
    ``compact_threshold`` is reached, then they are folded into per-kind
    flattened interval arrays (``compact()``). A timeline therefore holds
    at most ``compact_threshold`` raw records plus the (disjoint, hence
    bounded by trace structure, not record count) compacted arrays — a
    million activity records flatten in bounded memory. Compaction is
    lossy w.r.t. per-record identity (stream ids, kernel names) but exact
    w.r.t. the state occupancy the metrics are computed from.
    """

    device: int = 0
    records: List[DeviceRecord] = field(default_factory=list)
    compact_threshold: int = 65536
    _compact: Dict[DeviceActivity, np.ndarray] = field(
        default_factory=dict, init=False, repr=False
    )
    _span: Optional[Tuple[float, float]] = field(default=None, init=False, repr=False)
    _n_compacted: int = field(default=0, init=False, repr=False)

    @property
    def n_records(self) -> int:
        """Total records ever ingested (pending + already compacted)."""
        return self._n_compacted + len(self.records)

    def add(self, kind: DeviceActivity, start: float, end: float,
            stream: int = 0, name: str = "") -> None:
        self.records.append(DeviceRecord(kind, start, end, stream, name))
        if len(self.records) >= self.compact_threshold:
            self.compact()

    def extend(self, records: Iterable[DeviceRecord]) -> None:
        self.ingest(records)

    def ingest(self, records: Iterable, chunk_size: Optional[int] = None) -> int:
        """Stream records (``DeviceRecord`` or ``(kind, start, end[, stream,
        name])`` tuples) from any iterable, compacting every ``chunk_size``
        records so arbitrarily long streams are ingested in bounded memory.
        Returns the number of records ingested."""
        chunk = chunk_size or self.compact_threshold
        n = 0
        for rec in records:
            if not isinstance(rec, DeviceRecord):
                rec = DeviceRecord(*rec)
            self.records.append(rec)
            n += 1
            if len(self.records) >= chunk:
                self.compact()
        return n

    def compact(self) -> None:
        """Fold pending raw records into the per-kind flattened arrays."""
        if not self.records:
            return
        lo = min(r.start for r in self.records)
        hi = max(r.end for r in self.records)
        self._span = (
            (lo, hi) if self._span is None
            else (min(self._span[0], lo), max(self._span[1], hi))
        )
        for kind in DeviceActivity:
            pairs = [(r.start, r.end) for r in self.records if r.kind is kind]
            if not pairs:
                continue
            parts = [iv.as_intervals(pairs)]
            if kind in self._compact:
                parts.append(self._compact[kind])
            self._compact[kind] = iv.flatten(np.concatenate(parts, axis=0))
        self._n_compacted += len(self.records)
        self.records.clear()

    def kind_intervals(self, kind: DeviceActivity) -> np.ndarray:
        """Flattened intervals of one activity kind (compacted + pending)."""
        pairs = [(r.start, r.end) for r in self.records if r.kind is kind]
        base = self._compact.get(kind)
        if base is None:
            return iv.flatten(iv.as_intervals(pairs)) if pairs else iv.EMPTY.copy()
        if not pairs:
            return base.copy()
        return iv.flatten(np.concatenate([base, iv.as_intervals(pairs)], axis=0))

    def span(self) -> Tuple[float, float]:
        """(min start, max end) over every record ever ingested."""
        lo, hi = self._span if self._span is not None else (np.inf, -np.inf)
        for r in self.records:
            lo = min(lo, r.start)
            hi = max(hi, r.end)
        if lo > hi:
            return (0.0, 0.0)
        return (lo, hi)

    def occupancy(self, window: Optional[Tuple[float, float]] = None) -> DeviceOccupancy:
        kern = self.kind_intervals(DeviceActivity.KERNEL)
        mem = iv.subtract(self.kind_intervals(DeviceActivity.MEMORY), kern)
        if window is None:
            window = self.span()
        kern_c = iv.clip(kern, *window)
        mem_c = iv.clip(mem, *window)
        idle = iv.gaps(iv.union(kern_c, mem_c), *window)
        return DeviceOccupancy(
            kernel=iv.total(kern_c), memory=iv.total(mem_c), idle=iv.total(idle)
        )

    def state_intervals(self, window: Tuple[float, float]) -> Dict[DeviceState, np.ndarray]:
        """Disjoint per-state intervals over a window (for trace rendering)."""
        kern = iv.clip(self.kind_intervals(DeviceActivity.KERNEL), *window)
        mem = iv.clip(
            iv.subtract(self.kind_intervals(DeviceActivity.MEMORY), kern), *window
        )
        idle = iv.gaps(iv.union(kern, mem), *window)
        return {DeviceState.KERNEL: kern, DeviceState.MEMORY: mem, DeviceState.IDLE: idle}


@dataclass
class Trace:
    """One monitored region: host timelines per rank + device timelines.

    ``elapsed`` follows paper eq. (1): E = max_i (D_useful_i + D_not_useful_i)
    unless an explicit window is provided (then E = window span, which is
    what the online runtime backend uses).
    """

    hosts: Dict[int, HostTimeline] = field(default_factory=dict)
    devices: Dict[int, DeviceTimeline] = field(default_factory=dict)
    window: Optional[Tuple[float, float]] = None
    name: str = "Global"

    def host(self, rank: int) -> HostTimeline:
        if rank not in self.hosts:
            self.hosts[rank] = HostTimeline(rank=rank)
        return self.hosts[rank]

    def device(self, dev: int) -> DeviceTimeline:
        if dev not in self.devices:
            self.devices[dev] = DeviceTimeline(device=dev)
        return self.devices[dev]

    @property
    def elapsed(self) -> float:
        if self.window is not None:
            return self.window[1] - self.window[0]
        if not self.hosts:
            # device-only trace: use the union span of device activity
            spans = [
                d.occupancy().elapsed for d in self.devices.values()
            ]
            return max(spans, default=0.0)
        return max(h.elapsed for h in self.hosts.values())

    def device_occupancies(self) -> Dict[int, DeviceOccupancy]:
        win = self.window
        if win is None and self.hosts:
            win = (0.0, self.elapsed)
        return {d: tl.occupancy(win) for d, tl in self.devices.items()}
