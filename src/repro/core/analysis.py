"""Trace → metrics analysis for multi-rank / multi-device traces.

``TalpMonitor`` measures one process; ``Trace`` (built synthetically, by
the analytical backend, or merged from per-process JSON) carries the
whole job. This module computes the paper's host and device hierarchies
from a ``Trace`` — the aggregation step TALP performs at report time.
All metric arithmetic is routed through the façades into the declarative
engine (:data:`repro.core.hierarchy.HOST` / :data:`~.DEVICE`); no
formula is restated here.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .device_metrics import DeviceMetrics, device_metrics
from .host_metrics import HostMetrics, host_metrics
from .states import Trace
from .tree import MetricNode, device_tree, host_tree

__all__ = ["TraceAnalysis", "analyze_trace"]


@dataclass
class TraceAnalysis:
    host: Optional[HostMetrics]
    device: Optional[DeviceMetrics]
    elapsed: float
    host_states: Dict[int, Dict[str, float]]
    device_states: Dict[int, Dict[str, float]]
    name: str = "Global"

    def trees(self) -> Dict[str, MetricNode]:
        out: Dict[str, MetricNode] = {}
        if self.host is not None:
            out["host"] = host_tree(self.host)
        if self.device is not None:
            out["device"] = device_tree(self.device)
        return out

    def validate(self, tol: float = 1e-6) -> None:
        if self.host is not None:
            self.host.validate(tol)
        if self.device is not None:
            self.device.validate(tol)


def analyze_trace(
    trace: Trace,
    computational_efficiency: Optional[float] = None,
) -> TraceAnalysis:
    """Compute eqs. (6)–(12) for a complete job trace."""
    elapsed = trace.elapsed
    hm = None
    host_states: Dict[int, Dict[str, float]] = {}
    if trace.hosts:
        ranks = sorted(trace.hosts)
        useful = [trace.hosts[r].useful for r in ranks]
        offload = [trace.hosts[r].offload for r in ranks]
        mpi = [trace.hosts[r].mpi for r in ranks]
        hm = host_metrics(useful, offload, mpi, elapsed=elapsed)
        host_states = {r: trace.hosts[r].as_dict() for r in ranks}

    dm = None
    device_states: Dict[int, Dict[str, float]] = {}
    if trace.devices:
        occ = trace.device_occupancies()
        devs = sorted(occ)
        kernel = [occ[d].kernel for d in devs]
        memory = [occ[d].memory for d in devs]
        # Re-anchor idle to the job window: occupancy() computed idle
        # within the record span; the device-level idle in the paper is
        # relative to the elapsed time E.
        device_states = {
            d: {
                "kernel": occ[d].kernel,
                "memory": occ[d].memory,
                "idle": max(0.0, elapsed - occ[d].kernel - occ[d].memory),
            }
            for d in devs
        }
        if elapsed > 0:
            dm = device_metrics(
                kernel, memory, elapsed,
                computational_efficiency=computational_efficiency,
            )
    return TraceAnalysis(
        host=hm,
        device=dm,
        elapsed=elapsed,
        host_states=host_states,
        device_states=device_states,
        name=trace.name,
    )
