"""Multi-rank aggregation: merge per-rank TALP results into job-level ones.

The paper computes its host/device efficiency hierarchies (eqs. 6–12)
*across all ranks and devices of a job*. :class:`~repro.core.talp.TalpMonitor`
measures one process; this module is the central-aggregation step that
turns N per-rank :class:`TalpResult` payloads into the job-level report
TALP prints (per-rank collection + cheap central merge — the architecture
production job-monitoring systems use to scale).

Merge semantics:

  * **region-name union** — a region appears in the job report if any rank
    measured it; ranks that never entered it contribute nothing to that
    region's metrics (``n_ranks`` is per-region).
  * **host states** — kept per rank, keyed by the monitor's rank id; rank
    ids must be unique across the merged results.
  * **devices** — each rank's devices are distinct physical accelerators,
    so local device ids are remapped to dense job-global ids in
    (result-order, local-id) order. The remap is deterministic, which
    makes the merge associative: ``merge(merge(a, b), c) == merge(a, b, c)``.
  * **elapsed** — paper eq. (1): the job window is the max over ranks.
  * **metrics** — recomputed from the merged state durations (never
    averaged from per-rank metrics), so ``validate()`` multiplicativity
    holds exactly on the merged result.

Three transports move the per-rank payloads to the merge point:

  * :class:`InProcessGather` — ranks in one process (tests, simulated
    multi-rank runs, threads).
  * :class:`FileSpoolTransport` — each rank spools its payload to a
    shared directory: the versioned binary format by default
    (``talp_rank*.npz``: JSON header + NPZ timeline columns) or the
    legacy ``report.to_json`` text (``talp_rank*.json``); the merge side
    auto-detects either. Any process can merge the spool post mortem —
    TALP's "machine-readable output enabling automated processing" path,
    across nodes on a shared FS.
  * :class:`AllGatherTransport` — a ``jax.distributed``-style collective:
    with multiple initialized JAX processes the JSON payloads are
    exchanged via ``process_allgather`` so every rank obtains the job
    result; on a single process it degenerates to a local merge.

Post-mortem CLI: ``python -m repro.core.merge <spool_dir>`` (add
``--trace-out job.trace.json`` for a job-level Chrome/Perfetto trace
built from the merged result and any raw timeline attachments).
"""

from __future__ import annotations

import io
import itertools
import json
import os
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .collect import (
    FaultPlan,
    QuarantinedSpool,
    RankCoverage,
    SpoolPayloadError,
    SpoolVersionError,
    quarantine_spool,
    read_spool_payload,
    wait_for_ranks,
)
from .device_metrics import DeviceMetrics
from .hierarchy import DEVICE, HOST, StateDurations
from .host_metrics import HostMetrics
from .states import DeviceTimeline
from .talp import RegionResult, TalpResult
from .telemetry import overhead as _ovh

__all__ = [
    "merge_region_results",
    "merge_results",
    "merge_samples",
    "merge_step_series",
    "region_result_from_dict",
    "talp_result_from_json",
    "result_to_spool_bytes",
    "result_from_spool_bytes",
    "result_to_spool_json",
    "result_from_spool_json",
    "load_spool_payload",
    "InProcessGather",
    "FileSpoolTransport",
    "AllGatherTransport",
    "merge_spool",
    "emit_job_report",
    "RankCoverage",
    "QuarantinedSpool",
    "FaultPlan",
]

#: Per-process monotonic counter for unique temp names: concurrent
#: writers (threads, or two processes that were handed the same rank id)
#: must never share a temp file, or one can publish the other's
#: half-written bytes via ``os.replace``.
_TMP_SEQ = itertools.count()


def _tmp_name(path: str) -> str:
    return f"{path}.{os.getpid()}.{next(_TMP_SEQ)}.tmp"


def _fsync_write(path: str, data, mode: str) -> None:
    """Write + flush + fsync a temp file, then atomically publish it.
    Readers either see the old complete file or the new complete file —
    never a partial one, even across a crash mid-write."""
    tmp = _tmp_name(path)
    try:
        with open(tmp, mode) as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise

#: Version stamp of the binary spool payload (NPZ columns + JSON header).
SPOOL_BINARY_VERSION = 1


# ---------------------------------------------------------------------------
# core merge — metrics recomputed through the hierarchy engine
# ---------------------------------------------------------------------------
def _recompute_host(
    host_states: Dict[int, Dict[str, float]], elapsed: float,
    extras: Optional[Dict[str, float]] = None,
) -> Optional[HostMetrics]:
    if not host_states or elapsed <= 0:
        return None
    sd = StateDurations.from_states(
        host_states=host_states, elapsed=elapsed, extras=extras
    )
    return HostMetrics.from_frame(HOST.compute(sd))


def _recompute_device(
    device_states: Dict[int, Dict[str, float]], elapsed: float,
    extras: Optional[Dict[str, float]] = None,
) -> Optional[DeviceMetrics]:
    if not device_states or elapsed <= 0:
        return None
    sd = StateDurations.from_states(
        device_states=device_states, elapsed=elapsed, extras=extras
    )
    return DeviceMetrics.from_frame(DEVICE.compute(sd))


def merge_region_results(
    parts: Sequence[RegionResult], name: Optional[str] = None
) -> RegionResult:
    """Merge the same region measured by N ranks into one job-level result."""
    parts = list(parts)
    if not parts:
        raise ValueError("merge_region_results: empty input")
    name = name or parts[0].name
    elapsed = max(p.elapsed for p in parts)

    host_states: Dict[int, Dict[str, float]] = {}
    for p in parts:
        for rank, st in p.host_states.items():
            if rank in host_states:
                raise ValueError(
                    f"duplicate rank {rank} while merging region {name!r}; "
                    "give each monitor a distinct rank id"
                )
            host_states[rank] = dict(st)

    # Device-id remap: dense job-global ids in (part-order, local-id) order.
    # Idle is re-anchored to the job window (E may grow under the merge).
    device_states: Dict[int, Dict[str, float]] = {}
    gid = 0
    for p in parts:
        for dev in sorted(p.device_states):
            st = p.device_states[dev]
            k, m = st["kernel"], st["memory"]
            device_states[gid] = {
                "kernel": k,
                "memory": m,
                "idle": max(0.0, elapsed - k - m),
            }
            gid += 1

    # Self-overhead annotation: a wall-clock fraction does not compose
    # additively across ranks; the conservative job-level statement is
    # the worst rank's fraction (max-carry — absent unless some rank
    # measured it).
    overheads = [
        ov for ov in (getattr(p.host, "talp_overhead", None) for p in parts)
        if ov is not None
    ]
    extras = {"talp_overhead": max(overheads)} if overheads else None

    # Measured Computational Efficiency (FLOPs over peak·busy) composes
    # as the kernel-busy-weighted mean across ranks: Σ flops_i / (peak ·
    # Σ busy_i) with flops_i = CE_i · peak · busy_i. Busy per rank is the
    # sum of its device kernel durations, which the reduced states carry.
    ce_num = ce_den = 0.0
    for p in parts:
        ce = getattr(p.device, "computational_efficiency", None)
        if ce is None:
            continue
        busy = sum(st["kernel"] for st in p.device_states.values())
        ce_num += ce * busy
        ce_den += busy
    dev_extras = (
        {"computational_efficiency": ce_num / ce_den} if ce_den > 0 else None
    )

    return RegionResult(
        name=name,
        elapsed=elapsed,
        n_ranks=len(host_states),
        n_devices=len(device_states),
        host=_recompute_host(host_states, elapsed, extras=extras),
        device=_recompute_device(device_states, elapsed, extras=dev_extras),
        host_states=host_states,
        device_states=device_states,
    )


def merge_results(
    results: Sequence[TalpResult],
    name: Optional[str] = None,
    coverage: Optional[RankCoverage] = None,
) -> TalpResult:
    """Merge N per-rank :class:`TalpResult` payloads into the job result.

    ``coverage`` (a :class:`~repro.core.collect.RankCoverage`) annotates a
    *partial* merge — which ranks were expected, merged, missing or
    quarantined. It rides on the returned result's ``rank_coverage`` and
    is carried through the report JSON round trip, the text report, the
    telemetry exporter and the Chrome trace metadata; the merged metrics
    themselves are computed from exactly the results given, identically
    to a clean merge of those ranks.
    """
    results = list(results)
    if not results:
        raise ValueError("merge_results: empty input")
    region_names: List[str] = []
    for r in results:
        for rn in r.regions:
            if rn not in region_names:
                region_names.append(rn)
    merged = {
        rn: merge_region_results(
            [r.regions[rn] for r in results if rn in r.regions], name=rn
        )
        for rn in region_names
    }
    return TalpResult(
        name=name or results[0].name, regions=merged, rank_coverage=coverage
    )


def merge_samples(
    results: Sequence[TalpResult], name: Optional[str] = None
) -> TalpResult:
    """Merge mid-run snapshots (``TalpMonitor.sample_result()``) across
    ranks into a job-level snapshot — TALP's online mode at job scope.

    The algebra is identical to :func:`merge_results`: the snapshot
    window is the max elapsed over ranks, so ranks caught at different
    progress still merge into one internally consistent report
    (``validate()`` holds). On finalized runs the result agrees exactly
    with a post-mortem :func:`merge_results`.
    """
    return merge_results(results, name=name)


def merge_step_series(series_by_rank: Dict[int, "object"], name: str = "job"):
    """Rank-align per-rank step series into one job-level per-step table.

    Rows are aligned by ``(region name, step index)`` — step *k* of
    region *r* on every rank is the same logical step of the program, so
    the job-level row for it is computed across exactly those ranks.

    Host metrics are **recomputed** through the hierarchy engine (the
    merge-layer invariant: never average per-rank efficiencies): each
    step row carries its per-window ``useful``/``offload``/``mpi``
    durations, which stacked across ranks are precisely the
    :class:`~repro.core.hierarchy.StateDurations` HOST needs — so
    job-level per-step ``load_balance`` etc. are exact, including any
    ``with_child()`` host metric whose formula reads those inputs.
    Device-hierarchy columns (and any column the engine cannot rebuild
    from the carried inputs) are summarized as the across-rank mean —
    the per-device vectors behind them are not carried per step.

    Returns a :class:`~repro.core.telemetry.stepseries.StepSeries`
    holding the merged table; its base ``useful``/``offload``/``mpi``
    are across-rank sums and a trailing ``n_ranks`` column records
    coverage per row.
    """
    from .telemetry.stepseries import BASE_FIELDS, StepSeries

    if not series_by_rank:
        raise ValueError("merge_step_series: empty input")
    rank_rows: Dict[int, Dict[Tuple[str, int], np.void]] = {}
    metric_cols: List[str] = []
    for rank in sorted(series_by_rank):
        s = series_by_rank[rank]
        rows = s.rows()
        for c in s.metric_columns:
            if c not in metric_cols:
                metric_cols.append(c)
        by_key = rank_rows.setdefault(rank, {})
        for row in rows:
            by_key[(s.region_name(row["region"]), int(row["step"]))] = row
    keys = sorted(
        {k for by_key in rank_rows.values() for k in by_key},
        key=lambda k: (min(
            float(by_key[k]["t_open"])
            for by_key in rank_rows.values() if k in by_key
        ), k[0], k[1]),
    )
    out = StepSeries.from_arrays(
        rows=np.zeros(
            len(keys),
            dtype=np.dtype(
                list(BASE_FIELDS)
                + [(c, "f8") for c in metric_cols]
                + [("n_ranks", "f8")]
            ),
        ),
        regions=np.asarray([], dtype=np.str_),
        n_total=len(keys),
    )
    for i, (region, step) in enumerate(keys):
        parts = [
            by_key[(region, step)]
            for by_key in rank_rows.values()
            if (region, step) in by_key
        ]
        row = out._buf[i]
        rid = out._region_ids.get(region)
        if rid is None:
            rid = len(out._region_names)
            out._region_ids[region] = rid
            out._region_names.append(region)
        row["region"] = rid
        row["step"] = step
        row["t_open"] = min(float(p["t_open"]) for p in parts)
        row["t_close"] = max(float(p["t_close"]) for p in parts)
        elapsed = max(float(p["elapsed"]) for p in parts)
        row["elapsed"] = elapsed
        for f in ("useful", "offload", "mpi"):
            row[f] = sum(float(p[f]) for p in parts)
        row["n_ranks"] = len(parts)
        hvals: Dict[str, float] = {}
        if elapsed > 0:
            sd = StateDurations(
                elapsed=elapsed,
                useful=[float(p["useful"]) for p in parts],
                offload=[float(p["offload"]) for p in parts],
                mpi=[float(p["mpi"]) for p in parts],
            )
            hvals = HOST.compute(sd).values
        for c in metric_cols:
            hname, _, key = c.partition("_")
            if hname == "host" and key in hvals:
                row[c] = hvals[key]
                continue
            vals = [
                float(p[c]) for p in parts
                if c in (p.dtype.names or ()) and not np.isnan(p[c])
            ]
            row[c] = float(np.mean(vals)) if vals else np.nan
    # the table's identity, for CLI display
    out.name = name  # type: ignore[attr-defined]
    return out


# ---------------------------------------------------------------------------
# JSON reconstruction (the inverse of report.to_json, metrics recomputed)
# ---------------------------------------------------------------------------
def region_result_from_dict(d: Dict, name: Optional[str] = None) -> RegionResult:
    """Rebuild a :class:`RegionResult` from its ``report.to_json`` dict.

    Metrics are *recomputed* from the serialized state durations rather
    than trusted from the payload, so a merged result is always internally
    consistent (and ``validate()`` holds) even across producer versions.
    """
    name = name or d.get("name", "Global")
    elapsed = float(d["elapsed"])
    host_states = {
        int(r): {k: float(v) for k, v in st.items()}
        for r, st in (d.get("host_states") or {}).items()
    }
    device_states = {
        int(dev): {k: float(v) for k, v in st.items()}
        for dev, st in (d.get("device_states") or {}).items()
    }
    # talp_overhead and computational_efficiency are measurements (the
    # producer's self-cost / FLOP-model feed), not derivable from the
    # reduced states — they are the values trusted from the payload
    # rather than recomputed.
    ov = (d.get("host_metrics") or {}).get("talp_overhead")
    extras = {"talp_overhead": float(ov)} if ov is not None else None
    ce = (d.get("device_metrics") or {}).get("computational_efficiency")
    dev_extras = {"computational_efficiency": float(ce)} if ce is not None else None
    return RegionResult(
        name=name,
        elapsed=elapsed,
        n_ranks=len(host_states),
        n_devices=len(device_states),
        host=_recompute_host(host_states, elapsed, extras=extras),
        device=_recompute_device(device_states, elapsed, extras=dev_extras),
        host_states=host_states,
        device_states=device_states,
    )


def talp_result_from_json(text: str) -> TalpResult:
    """Rebuild a :class:`TalpResult` from ``report.to_json`` output."""
    payload = json.loads(text)
    if "regions" not in payload:
        # single-region payload: wrap it
        rr = region_result_from_dict(payload)
        return TalpResult(name=rr.name, regions={rr.name: rr})
    cov = payload.get("rank_coverage")
    return TalpResult(
        name=payload.get("talp", "talp"),
        regions={
            rn: region_result_from_dict(rd, name=rn)
            for rn, rd in payload["regions"].items()
        },
        rank_coverage=RankCoverage.from_dict(cov) if cov is not None else None,
    )


# ---------------------------------------------------------------------------
# spool payloads — versioned binary (NPZ columns) + JSON (legacy/reference)
# ---------------------------------------------------------------------------
def _timelines_header(
    timelines: Optional[Dict[int, DeviceTimeline]]
) -> Tuple[Dict[str, Dict], Dict[str, np.ndarray]]:
    """Split attached timelines into (per-device meta, named column arrays)."""
    meta: Dict[str, Dict] = {}
    arrays: Dict[str, np.ndarray] = {}
    for dev, tl in sorted((timelines or {}).items()):
        cols = tl.to_columns()
        meta[str(dev)] = cols["meta"]
        arrays[f"dev{dev}_pending"] = cols["pending"]
        arrays[f"dev{dev}_kernel"] = cols["kernel"]
        arrays[f"dev{dev}_memory"] = cols["memory"]
    return meta, arrays


def result_to_spool_bytes(
    result: TalpResult,
    timelines: Optional[Dict[int, DeviceTimeline]] = None,
) -> bytes:
    """Encode one rank's payload in the **binary spool format**: an NPZ
    container whose ``header`` entry is the UTF-8 JSON report (host
    states, region metadata — exactly the ``report.to_json`` dict, plus a
    version stamp) and whose remaining entries are the columnar device
    timelines (structured pending rows + flattened per-kind interval
    arrays). A million-record rank serializes with four array writes per
    device — no per-record encoding anywhere.
    """
    from .report import to_json

    tl_meta, arrays = _timelines_header(timelines)
    header = {
        "version": SPOOL_BINARY_VERSION,
        "format": "talp-spool",
        "result": json.loads(to_json(result)),
        "timelines": tl_meta,
    }
    buf = io.BytesIO()
    np.savez(
        buf,
        header=np.frombuffer(json.dumps(header).encode("utf-8"), dtype=np.uint8),
        **arrays,
    )
    return buf.getvalue()


def result_from_spool_bytes(
    data: bytes,
) -> Tuple[TalpResult, Dict[int, DeviceTimeline]]:
    """Decode :func:`result_to_spool_bytes` (metrics recomputed, exact
    timeline state reconstruction)."""
    with np.load(io.BytesIO(data), allow_pickle=False) as npz:
        header = json.loads(bytes(npz["header"]).decode("utf-8"))
        version = header.get("version")
        if version is None or version > SPOOL_BINARY_VERSION:
            raise SpoolVersionError(
                f"binary spool payload version {version!r} "
                f"(this reader supports <= {SPOOL_BINARY_VERSION})"
            )
        result = talp_result_from_json(json.dumps(header["result"]))
        timelines: Dict[int, DeviceTimeline] = {}
        for dev_s, meta in header.get("timelines", {}).items():
            dev = int(dev_s)
            timelines[dev] = DeviceTimeline.from_columns(
                pending=npz[f"dev{dev}_pending"],
                kernel=npz[f"dev{dev}_kernel"],
                memory=npz[f"dev{dev}_memory"],
                device=meta.get("device", dev),
                compact_threshold=meta.get("compact_threshold", 65536),
                n_compacted=meta.get("n_compacted", 0),
                span=meta.get("span"),
                n_kernel=meta.get("n_kernel"),
            )
    return result, timelines


def _timeline_to_json_obj(tl: DeviceTimeline) -> Dict:
    """Per-record JSON encoding of a timeline — the retained object-path
    reference the binary format is benchmarked against (and the shape the
    legacy JSON spool uses when timelines are attached)."""
    cols = tl.to_columns()
    pending = cols["pending"]
    return {
        **cols["meta"],
        "records": [
            [int(k), float(s), float(e), int(st)]
            for k, s, e, st in zip(
                pending["kind"], pending["start"],
                pending["end"], pending["stream"],
            )
        ],
        "kernel": cols["kernel"].tolist(),
        "memory": cols["memory"].tolist(),
    }


def _timeline_from_json_obj(d: Dict) -> DeviceTimeline:
    recs = np.asarray(d.get("records") or np.zeros((0, 4)), dtype=np.float64)
    recs = recs.reshape(-1, 4)
    from .recordio import RECORD_DTYPE

    pending = np.empty(len(recs), dtype=RECORD_DTYPE)
    pending["kind"] = recs[:, 0].astype(np.uint8)
    pending["start"] = recs[:, 1]
    pending["end"] = recs[:, 2]
    pending["stream"] = recs[:, 3].astype(np.uint32)
    return DeviceTimeline.from_columns(
        pending=pending,
        kernel=np.asarray(d.get("kernel") or np.zeros((0, 2))).reshape(-1, 2),
        memory=np.asarray(d.get("memory") or np.zeros((0, 2))).reshape(-1, 2),
        device=d.get("device", 0),
        compact_threshold=d.get("compact_threshold", 65536),
        n_compacted=d.get("n_compacted", 0),
        span=d.get("span"),
        n_kernel=d.get("n_kernel"),
    )


def result_to_spool_json(
    result: TalpResult,
    timelines: Optional[Dict[int, DeviceTimeline]] = None,
) -> str:
    """Legacy JSON spool payload (``report.to_json`` text); attached
    timelines are encoded per record under ``device_timelines``."""
    from .report import to_json

    if not timelines:
        return to_json(result)
    payload = json.loads(to_json(result))
    payload["device_timelines"] = {
        str(dev): _timeline_to_json_obj(tl)
        for dev, tl in sorted(timelines.items())
    }
    return json.dumps(payload, indent=2)


def result_from_spool_json(
    text: str,
) -> Tuple[TalpResult, Dict[int, DeviceTimeline]]:
    result = talp_result_from_json(text)
    payload = json.loads(text)
    timelines = {
        int(dev): _timeline_from_json_obj(d)
        for dev, d in (payload.get("device_timelines") or {}).items()
    }
    return result, timelines


def load_spool_payload(path: str) -> Tuple[TalpResult, Dict[int, DeviceTimeline]]:
    """Read one spool file, auto-detecting the payload format: ``.npz``
    files hold the versioned binary payload, anything else is parsed as
    (legacy) JSON. Returns ``(result, timelines)``; ``timelines`` is
    empty when the payload carries none."""
    if path.endswith(".npz"):
        with open(path, "rb") as f:
            return result_from_spool_bytes(f.read())
    with open(path) as f:
        return result_from_spool_json(f.read())


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
class InProcessGather:
    """Collect per-rank results in one process and merge on demand."""

    def __init__(self, world_size: Optional[int] = None):
        self.world_size = world_size
        self._results: Dict[int, TalpResult] = {}

    def submit(self, result: TalpResult, rank: int) -> None:
        if rank in self._results:
            raise ValueError(f"rank {rank} already submitted")
        self._results[rank] = result

    def ready(self) -> bool:
        if self.world_size is None:
            return bool(self._results)
        return len(self._results) >= self.world_size

    def merge(self, name: Optional[str] = None) -> TalpResult:
        if not self._results:
            raise ValueError("no results submitted")
        return merge_results(
            [self._results[r] for r in sorted(self._results)], name=name
        )


class FileSpoolTransport:
    """Per-rank spool on a shared filesystem.

    Each rank writes ``talp_rank<rank>.npz`` (versioned binary payload:
    JSON header + NPZ timeline columns, see
    :func:`result_to_spool_bytes`) or — with ``payload="json"`` —
    ``talp_rank<rank>.json`` (the legacy ``report.to_json`` text). The
    merge side lists the spool, auto-detects each file's format,
    reconstructs every per-rank result and merges; spools written by
    older (JSON-only) producers merge unchanged. Post-mortem by design:
    the spool is the job's machine-readable artifact and can be
    re-merged at any time.

    ``submit(..., timelines=...)`` optionally attaches raw per-device
    :class:`DeviceTimeline` state (columnar in the binary format,
    per-record JSON in the legacy one) so post-mortem tooling can
    re-window or re-render the activity, not just read the reduced
    states; :meth:`collect_timelines` reads them back.

    Use a fresh directory per job: leftover rank files from a previous
    run in the same directory would merge into the new report. Files
    whose rank id is outside ``[0, world_size)`` are rejected as stale;
    same-shape leftovers are indistinguishable from live ranks and are
    the caller's responsibility.
    """

    PREFIX = "talp_rank"
    SAMPLE_PREFIX = "talp_sample_rank"
    #: step-series spools are always NPZ (structured-array payload)
    STEP_PREFIX = "talp_steps_rank"
    #: recognised payload extensions, in collection preference order
    EXTS = (".npz", ".json")

    def __init__(self, spool_dir: str, world_size: Optional[int] = None,
                 payload: str = "binary"):
        if payload not in ("binary", "json"):
            raise ValueError(f"payload must be 'binary' or 'json', got {payload!r}")
        self.spool_dir = spool_dir
        self.world_size = world_size
        self.payload = payload
        os.makedirs(spool_dir, exist_ok=True)

    @property
    def _ext(self) -> str:
        return ".npz" if self.payload == "binary" else ".json"

    def _path(self, rank: int) -> str:
        return os.path.join(self.spool_dir, f"{self.PREFIX}{rank:05d}{self._ext}")

    def _sample_path(self, rank: int) -> str:
        return os.path.join(
            self.spool_dir, f"{self.SAMPLE_PREFIX}{rank:05d}{self._ext}"
        )

    def _find(self, rank: int, prefix: str) -> Optional[str]:
        for ext in self.EXTS:
            p = os.path.join(self.spool_dir, f"{prefix}{rank:05d}{ext}")
            if os.path.exists(p):
                return p
        return None

    def _publish(
        self,
        result: TalpResult,
        path: str,
        timelines: Optional[Dict[int, DeviceTimeline]] = None,
    ) -> str:
        # Atomic publish: a unique temp name per write (two writers
        # handed the same rank id must not interleave inside one temp
        # file), fsync before the rename (a crash mid-write must not
        # leave a torn file under the published name), then os.replace —
        # mergers only ever observe complete payloads.
        with _ovh.section("spool"):
            if path.endswith(".npz"):
                _fsync_write(path, result_to_spool_bytes(result, timelines),
                             "wb")
            else:
                _fsync_write(path, result_to_spool_json(result, timelines),
                             "w")
            return path

    def submit(
        self,
        result: TalpResult,
        rank: int,
        timelines: Optional[Dict[int, DeviceTimeline]] = None,
    ) -> str:
        return self._publish(result, self._path(rank), timelines)

    def submit_sample(
        self,
        result: TalpResult,
        rank: int,
        timelines: Optional[Dict[int, DeviceTimeline]] = None,
    ) -> str:
        """Publish this rank's latest mid-run snapshot (atomically
        overwritten on every call — the spool keeps one live snapshot per
        rank, next to the post-mortem ``talp_rank*`` files)."""
        return self._publish(result, self._sample_path(rank), timelines)

    def _scan_ranks(self, prefix: str) -> List[int]:
        try:
            names = os.listdir(self.spool_dir)
        except FileNotFoundError:
            return []
        ranks = set()
        for n in names:
            if not n.startswith(prefix):
                continue
            for ext in self.EXTS:
                if n.endswith(ext):
                    try:
                        ranks.add(int(n[len(prefix):-len(ext)]))
                    except ValueError:
                        pass
                    break
        return sorted(ranks)

    def spooled_ranks(self) -> List[int]:
        # SAMPLE_PREFIX does not share PREFIX as a prefix, so post-mortem
        # and snapshot files never alias each other in these scans.
        return self._scan_ranks(self.PREFIX)

    def sampled_ranks(self) -> List[int]:
        return self._scan_ranks(self.SAMPLE_PREFIX)

    def _check_stale(self, ranks: List[int]) -> None:
        # A spool dir is one job's artifact. Leftovers from a larger
        # previous run would silently merge into the new report; ranks
        # outside [0, world_size) are detectable — reject them.
        if self.world_size is not None and ranks and ranks[-1] >= self.world_size:
            raise ValueError(
                f"spool {self.spool_dir} contains rank {ranks[-1]} >= "
                f"world_size {self.world_size}; stale files from a previous "
                "job? use a fresh spool directory per job"
            )

    def ready(self) -> bool:
        ranks = self.spooled_ranks()
        self._check_stale(ranks)
        if self.world_size is None:
            return bool(ranks)
        return len(ranks) >= self.world_size

    def wait_for_ranks(
        self,
        max_wait: float,
        world_size: Optional[int] = None,
        poll: float = 0.05,
        backoff: float = 2.0,
        max_poll: float = 1.0,
    ) -> List[int]:
        """Deadline-based wait for straggler ranks: poll the spool with
        exponential backoff until ``world_size`` (defaulting to the
        transport's) rank files are present or ``max_wait`` seconds pass.
        Returns whatever ranks arrived — never raises; pair with
        ``merge(allow_missing=True)`` to proceed on a partial fleet."""
        return wait_for_ranks(
            self.spooled_ranks,
            world_size if world_size is not None else self.world_size,
            max_wait, poll=poll, backoff=backoff, max_poll=max_poll,
        )

    def collect(self) -> List[TalpResult]:
        ranks = self.spooled_ranks()
        self._check_stale(ranks)
        out = []
        for rank in ranks:
            path = self._find(rank, self.PREFIX)
            if path is not None:
                out.append(load_spool_payload(path)[0])
        return out

    def collect_tolerant(
        self,
        expected: Optional[int] = None,
        quarantine: bool = True,
    ) -> Tuple[Dict[int, TalpResult], List[QuarantinedSpool]]:
        """Fault-tolerant collection: read every rank payload that *can*
        be read, quarantine (never crash on) the rest.

        Unreadable payloads — truncated/zero-byte files, version
        mismatches, mangled JSON — are classified with a reason string
        and moved into ``<spool_dir>/quarantine/`` (with a
        ``.reason.json`` sidecar) so a re-merge stays clean; files whose
        rank id falls outside ``[0, expected)`` are quarantined as stale
        rather than raising like the strict path. Returns
        ``(results by rank, quarantined payload records)``.
        """
        world = expected if expected is not None else self.world_size
        results: Dict[int, TalpResult] = {}
        quarantined: List[QuarantinedSpool] = []

        def _quarantine(path: str, reason: str, rank: Optional[int]) -> None:
            dest = quarantine_spool(path, reason) if quarantine else None
            quarantined.append(QuarantinedSpool(
                path=path, reason=reason, rank=rank,
                quarantined_to=(os.path.relpath(dest, self.spool_dir)
                                if dest else None),
            ))

        for rank in self.spooled_ranks():
            path = self._find(rank, self.PREFIX)
            if path is None:
                continue
            if world is not None and rank >= world:
                _quarantine(
                    path,
                    f"rank id {rank} outside world size {world} "
                    "(stale file from a previous job?)",
                    rank,
                )
                continue
            try:
                results[rank] = read_spool_payload(path)[0]
            except SpoolPayloadError as e:
                _quarantine(path, str(e), rank)
        return results, quarantined

    def collect_timelines(self) -> Dict[int, Dict[int, DeviceTimeline]]:
        """Raw device-timeline attachments per spooled rank (empty dicts
        for ranks whose payload carries none)."""
        ranks = self.spooled_ranks()
        self._check_stale(ranks)
        out: Dict[int, Dict[int, DeviceTimeline]] = {}
        for rank in ranks:
            path = self._find(rank, self.PREFIX)
            if path is not None:
                out[rank] = load_spool_payload(path)[1]
        return out

    def merge(
        self,
        name: Optional[str] = None,
        allow_missing: bool = False,
        max_wait: Optional[float] = None,
        expected: Optional[int] = None,
    ) -> TalpResult:
        """Merge the spooled ranks into the job result.

        Strict by default: any unreadable payload raises, exactly as
        before. ``allow_missing=True`` switches to partial-rank mode:
        unreadable payloads are quarantined (see
        :meth:`collect_tolerant`), absent ranks are tolerated, and the
        result carries a ``rank_coverage`` annotation naming the
        expected/merged/missing/quarantined ranks. ``max_wait`` first
        waits (with poll backoff) up to that many seconds for straggler
        ranks to arrive; ``expected`` overrides the transport's
        ``world_size`` as the expectation coverage is measured against.
        """
        world = expected if expected is not None else self.world_size
        if max_wait is not None:
            self.wait_for_ranks(max_wait, world_size=world)
        if not allow_missing:
            results = self.collect()
            if not results:
                raise ValueError(f"no spooled results in {self.spool_dir}")
            return merge_results(results, name=name)
        by_rank, quarantined = self.collect_tolerant(expected=world)
        if not by_rank:
            raise ValueError(
                f"no readable spooled results in {self.spool_dir}"
                + (f" ({len(quarantined)} payload(s) quarantined)"
                   if quarantined else "")
            )
        coverage = RankCoverage.compute(
            merged=list(by_rank), expected=world, quarantined=quarantined
        )
        return merge_results(
            [by_rank[r] for r in sorted(by_rank)], name=name,
            coverage=coverage,
        )

    def collect_samples(self) -> List[TalpResult]:
        """Read every rank's latest mid-run snapshot currently present.

        Unlike :meth:`collect`, missing ranks are expected (a rank may not
        have published its first snapshot yet), so no staleness check —
        the job snapshot covers whichever ranks have reported so far.
        Unreadable snapshots are skipped rather than quarantined: the
        producer atomically overwrites its snapshot on the next sample,
        so moving the file aside would race with a live writer.
        """
        out = []
        for rank in self.sampled_ranks():
            path = self._find(rank, self.SAMPLE_PREFIX)
            if path is not None:
                try:
                    out.append(read_spool_payload(path)[0])
                except SpoolPayloadError:
                    continue
        return out

    def merge_samples(self, name: Optional[str] = None) -> TalpResult:
        """Job-level mid-run snapshot over the ranks sampled so far."""
        results = self.collect_samples()
        if not results:
            raise ValueError(f"no sample snapshots in {self.spool_dir}")
        return merge_samples(results, name=name)

    # -- step-resolution series -----------------------------------------
    def _step_path(self, rank: int) -> str:
        return os.path.join(
            self.spool_dir, f"{self.STEP_PREFIX}{rank:05d}.npz"
        )

    def submit_steps(self, series, rank: int) -> str:
        """Publish this rank's step series (atomic tmp + replace, like
        every spool write). Always NPZ — the structured row array *is*
        the schema, so readers need no hierarchy objects."""
        with _ovh.section("spool"):
            path = self._step_path(rank)
            buf = io.BytesIO()
            np.savez(buf, **series.to_arrays())
            _fsync_write(path, buf.getvalue(), "wb")
            return path

    def step_ranks(self) -> List[int]:
        return self._scan_ranks(self.STEP_PREFIX)

    def collect_steps(self) -> Dict[int, "object"]:
        """Read back every rank's spooled step series."""
        from .telemetry.stepseries import StepSeries

        out: Dict[int, StepSeries] = {}
        for rank in self.step_ranks():
            path = self._step_path(rank)
            if not os.path.exists(path):
                continue
            with np.load(path, allow_pickle=False) as npz:
                out[rank] = StepSeries.from_arrays(
                    rows=npz["rows"],
                    regions=npz["regions"],
                    n_total=int(npz["n_total"]),
                )
        return out

    def merge_steps(self, name: str = "job"):
        """Job-level per-step table across all spooled step series
        (see :func:`merge_step_series`)."""
        series = self.collect_steps()
        if not series:
            raise ValueError(f"no step-series spools in {self.spool_dir}")
        return merge_step_series(series, name=name)


class AllGatherTransport:
    """``jax.distributed``-style collective exchange of result payloads.

    With multiple initialized JAX processes, every rank contributes its
    JSON payload through ``multihost_utils.process_allgather`` (padded
    uint8 buffers, since collectives move arrays, not strings) and every
    rank returns the merged job result. On a single process — or when JAX
    distributed is unavailable — it degenerates to a local merge, so call
    sites need no gating.
    """

    def __init__(self, max_bytes: int = 1 << 20):
        self.max_bytes = max_bytes

    def gather(self, result: TalpResult, name: Optional[str] = None) -> TalpResult:
        from .report import to_json

        try:
            import jax

            n_proc = jax.process_count()
        except Exception:
            n_proc = 1
        if n_proc <= 1:
            return merge_results([result], name=name)

        import numpy as np
        from jax.experimental import multihost_utils

        payload = to_json(result).encode("utf-8")
        if len(payload) > self.max_bytes - 8:
            raise ValueError(
                f"result payload {len(payload)}B exceeds allgather buffer "
                f"{self.max_bytes}B; raise max_bytes"
            )
        buf = np.zeros(self.max_bytes, dtype=np.uint8)
        buf[:8] = np.frombuffer(
            len(payload).to_bytes(8, "little"), dtype=np.uint8
        )
        buf[8:8 + len(payload)] = np.frombuffer(payload, dtype=np.uint8)
        gathered = np.asarray(multihost_utils.process_allgather(buf))
        # Decode each rank's row defensively: a mangled or empty payload
        # (a rank that died between initializing the fleet and filling
        # its buffer, a producer-version skew) is quarantined with a
        # reason instead of failing the whole job report; the survivors
        # merge with a rank_coverage annotation.
        results: List[Tuple[int, TalpResult]] = []
        quarantined: List[QuarantinedSpool] = []
        for i, row in enumerate(gathered.reshape(n_proc, self.max_bytes)):
            size = int.from_bytes(row[:8].tobytes(), "little")
            try:
                if size == 0:
                    raise SpoolPayloadError("empty allgather payload")
                if size > self.max_bytes - 8:
                    raise SpoolPayloadError(
                        "oversized allgather payload",
                        f"claims {size}B in a {self.max_bytes}B buffer",
                    )
                results.append((i, talp_result_from_json(
                    row[8:8 + size].tobytes().decode("utf-8")
                )))
            except SpoolPayloadError as e:
                quarantined.append(QuarantinedSpool(
                    path=f"allgather rank {i}", reason=str(e), rank=i
                ))
            except (UnicodeDecodeError, json.JSONDecodeError, KeyError,
                    TypeError, ValueError) as e:
                quarantined.append(QuarantinedSpool(
                    path=f"allgather rank {i}",
                    reason=f"mangled allgather payload "
                           f"({type(e).__name__}: {e})",
                    rank=i,
                ))
        if not results:
            raise ValueError(
                f"allgather produced no decodable payloads across "
                f"{n_proc} process(es)"
            )
        coverage = None
        if quarantined:
            coverage = RankCoverage.compute(
                merged=[i for i, _ in results], expected=n_proc,
                quarantined=quarantined,
            )
        return merge_results(
            [r for _, r in results], name=name, coverage=coverage
        )

    def gather_sample(
        self, result: TalpResult, name: Optional[str] = None
    ) -> TalpResult:
        """Collective job-level mid-run snapshot: every rank contributes
        its ``TalpMonitor.sample_result()`` and obtains the merged
        snapshot. Same exchange as :meth:`gather` — the snapshot merge
        algebra (:func:`merge_samples`) is identical to the post-mortem
        one, only the inputs differ."""
        return self.gather(result, name=name)


def merge_spool(
    spool_dir: str,
    name: Optional[str] = None,
    allow_missing: bool = False,
    max_wait: Optional[float] = None,
    expected: Optional[int] = None,
) -> TalpResult:
    """One-shot post-mortem merge of a rank spool directory (reads binary
    and legacy JSON payloads alike). ``allow_missing``/``max_wait``/
    ``expected`` select the fault-tolerant partial-rank mode — see
    :meth:`FileSpoolTransport.merge`."""
    return FileSpoolTransport(spool_dir).merge(
        name=name, allow_missing=allow_missing, max_wait=max_wait,
        expected=expected,
    )


def emit_job_report(
    result: TalpResult,
    spool_dir: str,
    rank: int,
    world_size: int,
    verbose: bool = True,
    payload: str = "binary",
    timelines: Optional[Dict[int, DeviceTimeline]] = None,
    fault_plan: Optional[FaultPlan] = None,
) -> Optional[TalpResult]:
    """Launcher-side helper: spool this rank's report; once all ranks are
    in, merge and publish ``<spool_dir>/talp_job.json``.

    Multiple ranks may pass ``ready()`` near-simultaneously; the merge is
    idempotent and the job file is published atomically (unique tmp +
    ``os.replace``), so concurrent writers are safe — readers only ever
    see a complete report. Returns the job result on the rank(s) that
    merged, ``None`` elsewhere. The merged ``talp_job.json`` is always
    JSON (the job-level artifact stays human-readable); ``payload``
    selects the per-rank spool format.

    ``fault_plan`` (a :class:`~repro.core.collect.FaultPlan` or spec) is
    the drivers' ``--talp-fault-plan`` debug hook: it can drop this
    rank's submit entirely, delay it, or mangle the published payload —
    deterministic failure injection for exercising the tolerant-merge
    path end to end. When a plan is active, any rank that does merge
    merges tolerantly (``allow_missing=True``), since injected faults
    make unreadable peers the *expected* outcome.
    """
    from .report import render_tables, to_json

    transport = FileSpoolTransport(spool_dir, world_size=world_size,
                                   payload=payload)
    if fault_plan is not None:
        fault_plan = FaultPlan.from_spec(fault_plan)
        if fault_plan.drops(rank):
            if verbose:
                print(f"[talp fault] rank {rank}: dropping spool submit")
            return None
        delay = fault_plan.delay_s(rank)
        if delay:
            if verbose:
                print(f"[talp fault] rank {rank}: delaying submit {delay}s")
            time.sleep(delay)
    path = transport.submit(result, rank=rank, timelines=timelines)
    if fault_plan is not None:
        done = fault_plan.apply_to_file(path, rank)
        if done and verbose:
            print(f"[talp fault] rank {rank}: {done}")
    if not transport.ready():
        return None
    job = transport.merge(name=result.name,
                          allow_missing=fault_plan is not None)
    _fsync_write(os.path.join(spool_dir, "talp_job.json"), to_json(job), "w")
    if verbose:
        print(render_tables(job))
    return job


def main(argv: Optional[Sequence[str]] = None) -> None:
    import argparse
    import sys

    from .report import render_tables, to_json

    ap = argparse.ArgumentParser(
        description="Merge a per-rank TALP spool into the job-level report."
    )
    ap.add_argument("spool_dir",
                    help="directory of talp_rank*.npz (binary, default "
                         "producer format) and/or talp_rank*.json (legacy) "
                         "spool files; formats are auto-detected and mix "
                         "freely")
    ap.add_argument("--name", default=None, help="job name for the report")
    ap.add_argument("--allow-missing-ranks", action="store_true",
                    help="fault-tolerant partial merge: quarantine "
                         "unreadable spool payloads (truncated/zero-byte/"
                         "version-mismatched/mangled) instead of failing, "
                         "tolerate absent ranks, and annotate the report "
                         "with a rank_coverage node naming the expected/"
                         "merged/missing/quarantined ranks")
    ap.add_argument("--max-wait", type=float, default=None, metavar="SECONDS",
                    help="wait up to this many seconds (polling with "
                         "backoff) for straggler rank files to appear "
                         "before merging; needs --expected-ranks to know "
                         "when the spool is complete")
    ap.add_argument("--expected-ranks", type=int, default=None, metavar="N",
                    help="the job's world size: coverage is measured "
                         "against ranks [0, N) (default: inferred from "
                         "the highest rank id observed in the spool)")
    ap.add_argument("--json-out", default=None,
                    help="also write the merged report as JSON")
    ap.add_argument("--samples", action="store_true",
                    help="merge mid-run talp_sample_rank* snapshots "
                         "instead of post-mortem rank files")
    ap.add_argument("--trace-out", default=None,
                    help="write a job-level Chrome/Perfetto trace JSON "
                         "built from the merged result (device lanes are "
                         "exact when rank payloads attach raw timelines)")
    ap.add_argument("--step-series", action="store_true",
                    help="also merge talp_steps_rank*.npz step-series "
                         "spools into a job-level per-step table "
                         "(rank-aligned by step index; host metrics "
                         "recomputed across ranks) and print it")
    args = ap.parse_args(argv)

    # Diagnose before FileSpoolTransport, whose constructor would
    # silently create the missing directory.
    if not os.path.isdir(args.spool_dir):
        print(f"error: spool directory {args.spool_dir!r} does not exist",
              file=sys.stderr)
        sys.exit(2)
    transport = FileSpoolTransport(args.spool_dir)
    if args.max_wait is not None and not args.samples:
        transport.wait_for_ranks(args.max_wait,
                                 world_size=args.expected_ranks)
    pattern = (transport.SAMPLE_PREFIX if args.samples else transport.PREFIX)
    ranks = transport.sampled_ranks() if args.samples else transport.spooled_ranks()
    if not ranks:
        print(
            f"error: no {pattern}*.json or {pattern}*.npz files found in "
            f"{args.spool_dir!r}; nothing to merge",
            file=sys.stderr,
        )
        sys.exit(2)
    try:
        if args.samples:
            job = transport.merge_samples(name=args.name)
        else:
            job = transport.merge(
                name=args.name,
                allow_missing=args.allow_missing_ranks,
                expected=args.expected_ranks,
            )
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        sys.exit(2)
    print(render_tables(job))
    cov = job.rank_coverage
    if cov is not None and not cov.complete:
        print(f"warning: partial job report — {cov.summary()}; "
              f"missing={cov.missing} "
              f"quarantined={[q.rank for q in cov.quarantined]}",
              file=sys.stderr)
    if args.json_out:
        with open(args.json_out, "w") as f:
            f.write(to_json(job))
    if args.trace_out:
        from .telemetry.traceexport import export_job

        rank_tls = {} if args.samples else transport.collect_timelines()
        with open(args.trace_out, "w") as f:
            f.write(export_job(job, rank_tls))
        print(f"wrote Chrome trace: {args.trace_out}")
    if args.step_series:
        try:
            table = transport.merge_steps(name=args.name or job.name)
        except ValueError as e:
            print(f"error: {e}", file=sys.stderr)
            sys.exit(2)
        n_ranks = len(transport.step_ranks())
        print(
            f"\nJob-level step series ({n_ranks} rank(s), "
            f"{len(table)} aligned steps):"
        )
        print(table.as_table())


if __name__ == "__main__":
    main()
