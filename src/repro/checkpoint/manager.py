"""CheckpointManager: rotation, async (background-thread) saves, resume,
elastic restore.

Async saves snapshot the state to host memory synchronously (cheap
device→host copy) and write files in a worker thread, so the train loop
only blocks for the snapshot — the TALP host timeline shows this as a
short Offload window instead of a long Useful gap (checkpointing is one
of the classic Orchestration-Efficiency sinks the paper's metrics
expose).
"""

from __future__ import annotations

import threading
from typing import Any, Optional, Tuple

import jax

from .checkpointer import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)

__all__ = ["CheckpointManager"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._worker: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def wait(self) -> None:
        """Block until any in-flight save completes (and re-raise errors)."""
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_state: Any) -> None:
        try:
            save_checkpoint(self.directory, step, host_state)
            self._rotate()
        except BaseException as e:  # surfaced on next wait()/save()
            self._error = e

    def _rotate(self) -> None:
        steps = list_steps(self.directory)
        for s in steps[: -self.keep] if self.keep > 0 else []:
            import shutil, os
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------
    def save(self, step: int, state: Any) -> None:
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: jax.device_get(x), state)
        if self.async_save:
            self._worker = threading.Thread(
                target=self._write, args=(step, host_state), daemon=True
            )
            self._worker.start()
        else:
            self._write(step, host_state)
            if self._error is not None:
                err, self._error = self._error, None
                raise err

    def restore_latest(
        self, target: Any, shardings: Any = None
    ) -> Tuple[Optional[Any], int]:
        """(state, next_step); (None, 0) when no checkpoint exists."""
        self.wait()
        step = latest_step(self.directory)
        if step is None:
            return None, 0
        state = restore_checkpoint(self.directory, step, target, shardings)
        return state, step + 1
