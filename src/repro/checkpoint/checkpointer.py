"""Checkpoint serialization: pytree ↔ directory of array files + manifest.

Layout (one checkpoint):
    <dir>/step_<N>/
        manifest.json       # tree structure, shapes, dtypes, step
        <leaf-key>.npy      # one file per leaf

Writes are crash-safe: everything lands in ``step_<N>.tmp`` and is
atomically renamed once the manifest is fsynced — a half-written
checkpoint is never visible to ``latest_step``. Restore accepts target
shardings, so a checkpoint written on one mesh can be loaded onto a
different mesh/device-count (elastic rescaling): arrays are stored
unsharded per leaf and re-placed with ``jax.device_put``.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step", "list_steps"]

_STEP_RE = re.compile(r"^step_(\d+)$")

# ml_dtypes types don't survive np.save/np.load on their own: store them
# as same-width uint views and record the true dtype in the manifest.
_EXOTIC_STORE = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _to_storable(arr: np.ndarray):
    name = arr.dtype.name
    if name in _EXOTIC_STORE:
        return arr.view(_EXOTIC_STORE[name]), name
    return arr, name


def _from_storable(arr: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _EXOTIC_STORE:
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def _leaf_key(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "__".join(parts) or "root"


def save_checkpoint(directory: str, step: int, state: Any) -> str:
    """Write a checkpoint; returns the final path."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    entries: List[Dict[str, Any]] = []
    for path, leaf in leaves:
        key = _leaf_key(path)
        arr = np.asarray(jax.device_get(leaf))
        storable, dtype_name = _to_storable(arr)
        np.save(os.path.join(tmp, key + ".npy"), storable)
        entries.append({
            "key": key,
            "shape": list(arr.shape),
            "dtype": dtype_name,
        })
    manifest = {"step": step, "leaves": entries}
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    steps = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            steps.append(int(m.group(1)))
    return sorted(steps)


def latest_step(directory: str) -> Optional[int]:
    steps = list_steps(directory)
    return steps[-1] if steps else None


def restore_checkpoint(
    directory: str,
    step: int,
    target: Any,
    shardings: Any = None,
) -> Any:
    """Load ``step`` into the structure of ``target`` (a pytree of arrays
    or ShapeDtypeStructs). With ``shardings`` (a matching pytree of
    ``jax.sharding.Sharding``), leaves are placed sharded — this is the
    elastic-reshard path."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    available = {e["key"]: e for e in manifest["leaves"]}

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = (
        jax.tree_util.tree_leaves(shardings) if shardings is not None
        else [None] * len(leaves_with_path)
    )
    if shardings is not None and len(shard_leaves) != len(leaves_with_path):
        raise ValueError("shardings tree does not match target tree")

    out = []
    for (p, leaf), sh in zip(leaves_with_path, shard_leaves):
        key = _leaf_key(p)
        if key not in available:
            raise KeyError(f"checkpoint {path} missing leaf {key}")
        arr = np.load(os.path.join(path, key + ".npy"))
        arr = _from_storable(arr, available[key]["dtype"])
        want_shape = tuple(leaf.shape)
        if tuple(arr.shape) != want_shape:
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != target {want_shape}"
            )
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else
                   jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
