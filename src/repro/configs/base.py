"""Configuration system: model configs, input-shape configs, registry.

Every assigned architecture is a frozen ``ModelConfig``; shapes are the
four assigned input-shape sets. ``--arch <id>`` resolves through
:func:`get_config`; reduced smoke variants via :func:`smoke_config`.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional, Tuple

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "register_config",
    "get_config",
    "list_configs",
    "smoke_config",
]


def _round_up(x: int, mult: int) -> int:
    return (x + mult - 1) // mult * mult


@dataclass(frozen=True)
class ModelConfig:
    # identity
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str = ""                  # citation from the assignment table
    # trunk
    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0                 # 0 → d_model // num_heads
    d_ff: int = 0                     # dense FFN width (0 → no FFN)
    vocab_size: int = 0
    # layer pattern: tuple of block kinds forming one scan "super-layer";
    # repeated num_layers // len(pattern) times.
    pattern: Tuple[str, ...] = ("attn",)   # attn | attn_local | attn_global | ssm | shared_attn
    # attention features
    window: Optional[int] = None       # sliding-window size (SWA / local layers)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    rope_theta: float = 10000.0
    mrope_sections: Optional[Tuple[int, ...]] = None   # M-RoPE (qwen2-vl)
    # MoE
    num_experts: int = 0
    num_experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_group_size: int = 1024         # tokens per dispatch group
    moe_pad_experts_to: int = 0        # pad expert dim (dead experts) so
    #                                    it divides the model axis → EP
    # SSM (Mamba-2 / SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1
    # frontend: "token" (embedding table) or "embed" (precomputed
    # patch/frame embeddings — VLM/audio stub per assignment)
    frontend: str = "token"
    # numerics / execution
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_kv_chunk: int = 1024          # chunked-attention KV block
    loss_chunk: int = 16384            # chunked cross-entropy block
    remat: str = "full"                # full | none
    scan_layers: bool = True           # lax.scan stack (False: unrolled)
    decode_hot_len: int = 128          # mutable hot-ring slots per cache
    embed_onehot: bool = False         # one-hot matmul embedding — §Perf
    #                                    iter C5: refuted (one-hot traffic
    #                                    outweighs the fp32-gather psum)
    # notes (e.g. long_500k applicability)
    long_context_ok: bool = False

    # ---- derived ----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(1, self.num_heads)

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def repeats(self) -> int:
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers {self.num_layers} not divisible by "
                f"pattern period {len(self.pattern)}"
            )
        return self.num_layers // len(self.pattern)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def moe_experts_physical(self) -> int:
        return max(self.num_experts, self.moe_pad_experts_to)

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND model-FLOPs accounting)."""
        m, v = self.d_model, self.padded_vocab
        total = 0
        if self.frontend == "token":
            total += v * m
        total += v * m  # unembed
        hd = self.resolved_head_dim
        per_kind: Dict[str, int] = {}
        attn = m * (self.num_heads * hd) + 2 * m * (self.num_kv_heads * hd) \
            + (self.num_heads * hd) * m
        dense_ffn = 3 * m * self.d_ff if self.d_ff else 0
        moe_ffn = (
            self.moe_experts_physical * 3 * m * self.moe_d_ff
            + m * self.num_experts
            if self.is_moe
            else 0
        )
        ffn = moe_ffn if self.is_moe else dense_ffn
        per_kind["attn"] = attn + ffn + 2 * m
        per_kind["attn_local"] = per_kind["attn"]
        per_kind["attn_global"] = per_kind["attn"]
        per_kind["shared_attn"] = per_kind["attn"]  # counted once below
        d_in = self.ssm_d_inner
        n, h = self.ssm_state, self.ssm_heads
        per_kind["ssm"] = (
            m * d_in * 2                      # Wz, Wx
            + 2 * m * (self.ssm_groups * n)   # WB, WC
            + m * h                           # Wdt
            + d_in * m                        # out
            + 2 * m                           # norms
        )
        shared_seen = False
        for r in range(self.repeats):
            for kind in self.pattern:
                if kind == "shared_attn":
                    if not shared_seen:
                        total += per_kind["shared_attn"]
                        shared_seen = True
                else:
                    total += per_kind[kind]
        return total

    def n_active_params(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.n_params()
        m = self.d_model
        inactive = (
            (self.moe_experts_physical - self.num_experts_per_token)
            * 3 * m * self.moe_d_ff
        ) * self.num_layers
        return self.n_params() - inactive

    def n_flops_params(self) -> int:
        """Params that contribute matmul FLOPs per token: active params
        minus the input-embedding table (a gather, not a matmul). This is
        the 6·N·D / 2·N·D numerator."""
        n = self.n_active_params()
        if self.frontend == "token":
            n -= self.padded_vocab * self.d_model
        return n


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode
    # decode shapes: one new token against a cache of seq_len


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


_CONFIGS: Dict[str, Callable[[], ModelConfig]] = {}


def register_config(name: str):
    def deco(fn: Callable[[], ModelConfig]):
        _CONFIGS[name] = fn
        return fn
    return deco


def get_config(name: str) -> ModelConfig:
    if name not in _CONFIGS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_CONFIGS)}")
    return _CONFIGS[name]()


def list_configs():
    return sorted(_CONFIGS)


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests: few layers, small
    width, few experts, tiny vocab — structure preserved."""
    cfg = get_config(name)
    period = len(cfg.pattern)
    updates = dict(
        num_layers=2 * period,
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=512,
        num_heads=max(2, min(4, cfg.num_heads)) if cfg.num_heads else 0,
        num_kv_heads=0,
        head_dim=16 if cfg.num_heads else 0,
        window=min(cfg.window, 64) if cfg.window else None,
        moe_group_size=64,
        loss_chunk=256,
        attn_kv_chunk=64,
        decode_hot_len=16,
        ssm_chunk=32,
        ssm_head_dim=16 if cfg.ssm_state else 64,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
    )
    if cfg.num_heads:
        nh = updates["num_heads"]
        # preserve GQA grouping where possible
        ratio = max(1, cfg.num_heads // max(1, cfg.num_kv_heads))
        updates["num_kv_heads"] = max(1, nh // min(ratio, nh))
    if cfg.is_moe:
        # capacity_factor 8 ⇒ no token drops at smoke scale, making
        # outputs batch-context-invariant (prefill/decode comparable)
        updates.update(num_experts=4, num_experts_per_token=2, moe_d_ff=64,
                       capacity_factor=8.0)
    if cfg.mrope_sections:
        updates["mrope_sections"] = (2, 3, 3)  # sums to head_dim/2 = 8
    return replace(cfg, **updates)
