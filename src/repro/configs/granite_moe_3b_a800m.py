"""granite-moe-3b-a800m — fine-grained MoE.

[moe] 32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf].
"""

from .base import ModelConfig, register_config


@register_config("granite-moe-3b-a800m")
def granite_moe_3b_a800m() -> ModelConfig:
    return ModelConfig(
        name="granite-moe-3b-a800m",
        family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        num_layers=32,
        d_model=1536,
        num_heads=24,
        num_kv_heads=8,
        d_ff=0,
        vocab_size=49155,      # padded to 49408
        pattern=("attn",),
        num_experts=40,
        num_experts_per_token=8,
        moe_d_ff=512,
        # small E ⇒ capacity C = gs·k/E·cf explodes with group size;
        # 256-token groups keep C at 64 (§Perf iter A1)
        moe_group_size=256,
        # 40 ∤ 16: pad to 48 dead-expert slots so the expert dim shards
        # over the 16-way model axis (EP) — §Perf iter A6
        moe_pad_experts_to=48,
        rope_theta=10000.0,
    )
