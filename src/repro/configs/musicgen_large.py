"""musicgen-large — decoder-only over EnCodec tokens (audio frontend stubbed).

[audio] 48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048 —
decoder-only over EnCodec tokens [arXiv:2306.05284; hf]. Backbone only:
``input_specs()`` provides precomputed frame embeddings
(``frontend="embed"``); the EnCodec quantizer stack is out of scope per
the assignment.
"""

from .base import ModelConfig, register_config


@register_config("musicgen-large")
def musicgen_large() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        source="arXiv:2306.05284",
        num_layers=48,
        d_model=2048,
        num_heads=32,
        num_kv_heads=32,       # MHA
        d_ff=8192,
        vocab_size=2048,
        pattern=("attn",),
        rope_theta=10000.0,
        frontend="embed",
        long_context_ok=False,  # pure full attention → long_500k skipped
    )
