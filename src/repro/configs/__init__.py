"""Architecture registry: importing this package registers all assigned
architectures. ``get_config("<id>")`` / ``--arch <id>`` resolve here."""

from .base import (
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_config,
    list_configs,
    register_config,
    smoke_config,
)

# importing registers each config
from . import (  # noqa: F401
    gemma2_2b,
    granite_moe_3b_a800m,
    h2o_danube_3_4b,
    llama3_2_3b,
    mamba2_130m,
    musicgen_large,
    qwen2_vl_72b,
    qwen3_moe_235b_a22b,
    starcoder2_15b,
    zamba2_2_7b,
)

ALL_ARCHS = list_configs()

__all__ = [
    "ModelConfig",
    "ShapeConfig",
    "SHAPES",
    "get_config",
    "list_configs",
    "register_config",
    "smoke_config",
    "ALL_ARCHS",
]
