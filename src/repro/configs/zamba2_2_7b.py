"""zamba2-2.7b — Mamba-2 trunk with shared attention blocks.

[hybrid] 54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000,
ssm_state=64 — Mamba2 + shared attn blocks [arXiv:2411.15242; hf].

Pattern: every 6th layer is an attention+MLP block whose *weights are
shared* across all applications (one parameter set, 9 distinct KV
caches), the rest are Mamba-2 SSD blocks — the Zamba-2 design.
"""

from .base import ModelConfig, register_config


@register_config("zamba2-2.7b")
def zamba2_2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-2.7b",
        family="hybrid",
        source="arXiv:2411.15242",
        num_layers=54,
        d_model=2560,
        num_heads=32,
        num_kv_heads=32,       # MHA in the shared block
        d_ff=10240,
        vocab_size=32000,
        pattern=("ssm", "ssm", "ssm", "ssm", "ssm", "shared_attn"),
        ssm_state=64,
        ssm_head_dim=64,
        ssm_expand=2,          # d_inner = 5120, 80 SSD heads
        rope_theta=10000.0,
        long_context_ok=True,  # SSM + a few attn blocks → long_500k runs
    )
