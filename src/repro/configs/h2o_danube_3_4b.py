"""h2o-danube-3-4b — dense llama/mistral mix with sliding-window attention.

[dense] 24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000 —
llama+mistral mix, SWA [arXiv:2401.16818; unverified].
"""

from .base import ModelConfig, register_config


@register_config("h2o-danube-3-4b")
def h2o_danube_3_4b() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        source="arXiv:2401.16818",
        num_layers=24,
        d_model=3840,
        num_heads=32,
        num_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        pattern=("attn",),
        window=4096,           # mistral-style SWA at every layer
        rope_theta=10000.0,
        # windowed cache is bounded → long_500k runs (sub-quadratic)
        long_context_ok=True,
    )
