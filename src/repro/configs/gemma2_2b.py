"""gemma2-2b — alternating local/global attention with logit soft-capping.

[dense] 26L d_model=2304 8H (GQA kv=4) d_ff=9216 vocab=256000 —
local+global alternating, logit softcap [arXiv:2408.00118; hf].
"""

from .base import ModelConfig, register_config


@register_config("gemma2-2b")
def gemma2_2b() -> ModelConfig:
    return ModelConfig(
        name="gemma2-2b",
        family="dense",
        source="arXiv:2408.00118",
        num_layers=26,
        d_model=2304,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=9216,
        vocab_size=256000,
        pattern=("attn_local", "attn_global"),  # period-2 alternation
        window=4096,                            # local layers
        attn_logit_softcap=50.0,
        final_logit_softcap=30.0,
        rope_theta=10000.0,
        # local layers bounded; global layers sequence-sharded KV →
        # long_500k runs (alternating, not pure full attention)
        long_context_ok=True,
    )
