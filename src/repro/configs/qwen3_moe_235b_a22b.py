"""qwen3-moe-235b-a22b — large sparse MoE.

[moe] 94L d_model=4096 64H (GQA kv=4) d_ff=1536 (per expert)
vocab=151936, MoE 128e top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
"""

from .base import ModelConfig, register_config


@register_config("qwen3-moe-235b-a22b")
def qwen3_moe_235b_a22b() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b",
        family="moe",
        source="hf:Qwen/Qwen3-30B-A3B",
        num_layers=94,
        d_model=4096,
        num_heads=64,
        num_kv_heads=4,
        head_dim=128,
        d_ff=0,
        vocab_size=151936,     # padded to 152064
        pattern=("attn",),
        num_experts=128,
        num_experts_per_token=8,
        moe_d_ff=1536,
        # dispatch groups must not cross sequence-parallel shard
        # boundaries (4096-token rows / 16-way SP = 256-token shards):
        # shard-local grouping keeps the (g,gs,m) reshape collective-free
        # (§Perf iter C3)
        moe_group_size=256,
        rope_theta=1000000.0,
    )
