"""mamba2-130m — pure SSM (attention-free), SSD core.

[ssm] 24L d_model=768 (attn-free) d_ff=0 vocab=50280, ssm_state=128 —
SSD (state-space duality) [arXiv:2405.21060; unverified].
"""

from .base import ModelConfig, register_config


@register_config("mamba2-130m")
def mamba2_130m() -> ModelConfig:
    return ModelConfig(
        name="mamba2-130m",
        family="ssm",
        source="arXiv:2405.21060",
        num_layers=24,
        d_model=768,
        num_heads=0,
        num_kv_heads=0,
        d_ff=0,
        vocab_size=50280,
        pattern=("ssm",),
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,          # d_inner = 1536, 24 SSD heads
        ssm_chunk=256,
        long_context_ok=True,  # constant-size state: long_500k runs
    )
