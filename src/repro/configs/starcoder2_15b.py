"""starcoder2-15b — dense code model, GQA + RoPE.

[dense] 40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152
[arXiv:2402.19173; hf].
"""

from .base import ModelConfig, register_config


@register_config("starcoder2-15b")
def starcoder2_15b() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-15b",
        family="dense",
        source="arXiv:2402.19173",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=4,
        d_ff=24576,
        vocab_size=49152,
        pattern=("attn",),
        rope_theta=100000.0,
        long_context_ok=False,  # pure full attention → long_500k skipped
    )
