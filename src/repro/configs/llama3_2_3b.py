"""llama3.2-3b — small dense llama3.

[dense] 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B; unverified].
"""

from .base import ModelConfig, register_config


@register_config("llama3.2-3b")
def llama3_2_3b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b",
        family="dense",
        source="hf:meta-llama/Llama-3.2-1B",
        num_layers=28,
        d_model=3072,
        num_heads=24,
        num_kv_heads=8,
        d_ff=8192,
        vocab_size=128256,
        pattern=("attn",),
        rope_theta=500000.0,
        # pure full attention at every layer → long_500k skipped
        long_context_ok=False,
    )
