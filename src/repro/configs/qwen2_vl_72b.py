"""qwen2-vl-72b — VLM backbone with M-RoPE (modality frontend stubbed).

[vlm] 80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064 — M-RoPE,
dynamic resolution [arXiv:2409.12191; hf]. Per the assignment, this
entry is the transformer BACKBONE only: ``input_specs()`` provides
precomputed patch embeddings (``frontend="embed"``).
"""

from .base import ModelConfig, register_config


@register_config("qwen2-vl-72b")
def qwen2_vl_72b() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        source="arXiv:2409.12191",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        pattern=("attn",),
        mrope_sections=(16, 24, 24),   # temporal/height/width, half-dim 64
        rope_theta=1000000.0,
        frontend="embed",
        long_context_ok=False,  # pure full attention → long_500k skipped
    )
