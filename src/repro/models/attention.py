"""GQA attention with sliding-window, logit soft-capping, RoPE/M-RoPE,
chunked (memory-efficient) softmax, prefill-cache construction and
ring-buffer decode.

Memory model: training/prefill never materializes the full (S × T) score
matrix — scores are computed per KV chunk under a ``lax.scan`` with an
online-softmax carry (the XLA-path analogue of the Pallas flash kernel in
``repro.kernels.flash_attention``; the kernel is the TPU hot-path, this
is the portable path and the oracle's algorithmic twin).

Decode uses a uniform ring-buffer cache: every slot remembers the token
position it holds (``kv_pos``), so full-attention and windowed layers
share one code path (mask = slot holds a token within the window).
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.act_sharding import constrain_seq_gathered
from .common import apply_mrope, apply_rope, rms_norm, soft_cap, truncated_normal

__all__ = [
    "init_attn_params",
    "attn_forward",
    "init_kv_cache",
    "attn_decode",
    "chunked_attention",
]

NEG_INF = -1e30


def init_attn_params(key, cfg) -> Dict[str, jax.Array]:
    m = cfg.d_model
    hd = cfg.resolved_head_dim
    h, k = cfg.num_heads, cfg.num_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4)
    return {
        "wq": truncated_normal(keys[0], (m, h * hd), 1.0, dtype),
        "wk": truncated_normal(keys[1], (m, k * hd), 1.0, dtype),
        "wv": truncated_normal(keys[2], (m, k * hd), 1.0, dtype),
        "wo": truncated_normal(keys[3], (h * hd, m), 1.0, dtype),
    }


def _project_qkv(cfg, p, h):
    b, s, _ = h.shape
    hd = cfg.resolved_head_dim
    nh, nk = cfg.num_heads, cfg.num_kv_heads
    cdt = h.dtype
    q = (h @ p["wq"].astype(cdt)).reshape(b, s, nh, hd)
    k = (h @ p["wk"].astype(cdt)).reshape(b, s, nk, hd)
    v = (h @ p["wv"].astype(cdt)).reshape(b, s, nk, hd)
    return q, k, v


def _rope(cfg, x, positions):
    if cfg.mrope_sections is not None:
        return apply_mrope(x, positions, cfg.mrope_sections, cfg.rope_theta)
    return apply_rope(x, positions, cfg.rope_theta)


def _pos_1d(positions):
    """positions may be (B,S) or (3,B,S) (M-RoPE); masks use stream 0."""
    return positions[0] if positions.ndim == 3 else positions


def attention_parts(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)
    v: jax.Array,            # (B, T, K, D)
    q_pos: jax.Array,        # (B, S)
    kv_pos: jax.Array,       # (B, T)  (-1 = empty slot)
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_chunk: int = 1024,
):
    """Unnormalized online-softmax accumulation over one KV source.

    Returns (m, l, acc): running max (B,S,K,G), denominator and fp32
    accumulator (B,S,K,G,D). Multiple sources (e.g. a frozen prefix
    cache + a hot decode buffer) combine exactly via
    :func:`combine_parts` — the flash-decoding split-softmax identity.
    """
    b, s, h, d = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = h // nk
    # Keep q/k/v in compute dtype across any resharding boundary — the
    # MXU takes bf16 inputs with fp32 accumulation, and casting early
    # doubles the SP all-gather bytes (§Perf iter C1).
    qr = q.reshape(b, s, nk, g, d) * jnp.asarray(d ** -0.5, q.dtype)
    kv_chunk = min(kv_chunk, t)
    if t % kv_chunk != 0:
        pad = kv_chunk - t % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, ((0, 0), (0, pad)), constant_values=-1)
        t = t + pad
    nc = t // kv_chunk
    # (nc, B, C, K, D) chunk-major for scan
    kc = jnp.moveaxis(k.reshape(b, nc, kv_chunk, nk, d), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, kv_chunk, nk, d), 1, 0)
    pc = jnp.moveaxis(kv_pos.reshape(b, nc, kv_chunk), 1, 0)

    def body(carry, xs):
        m_i, l_i, acc = carry
        k_i, v_i, p_i = xs
        sc = jnp.einsum(
            "bskgd,bckd->bskgc", qr, k_i,
            preferred_element_type=jnp.float32,
        )
        sc = soft_cap(sc, softcap)
        valid = (p_i[:, None, :] >= 0) & (p_i[:, None, :] <= q_pos[:, :, None])
        if window is not None:
            valid &= p_i[:, None, :] > (q_pos[:, :, None] - window)
        sc = jnp.where(valid[:, :, None, None, :], sc, NEG_INF)
        m_new = jnp.maximum(m_i, jnp.max(sc, axis=-1))
        alpha = jnp.exp(m_i - m_new)
        pexp = jnp.exp(sc - m_new[..., None])
        l_new = l_i * alpha + jnp.sum(pexp, axis=-1)
        # NOTE (§Perf iter B4, refuted): casting pexp to bf16 for the p·V
        # matmul ADDED a materialized convert buffer (+3% memory term) —
        # XLA already fuses the fp32 path. Keeping fp32; the real fix for
        # score traffic is the Pallas flash kernel (scores stay in VMEM).
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bskgc,bckd->bskgd", pexp, v_i.astype(jnp.float32),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, s, nk, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, s, nk, g), jnp.float32)
    a0 = jnp.zeros((b, s, nk, g, d), jnp.float32)
    if nc == 1:
        (m_f, l_f, acc), _ = body((m0, l0, a0), (kc[0], vc[0], pc[0]))
    else:
        (m_f, l_f, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    return m_f, l_f, acc


def combine_parts(parts, out_shape, dtype):
    """Merge (m, l, acc) partial softmaxes from independent KV sources."""
    m = parts[0][0]
    for mp, _, _ in parts[1:]:
        m = jnp.maximum(m, mp)
    l_tot = 0.0
    acc_tot = 0.0
    for mp, lp, ap in parts:
        alpha = jnp.exp(mp - m)
        l_tot = l_tot + lp * alpha
        acc_tot = acc_tot + ap * alpha[..., None]
    out = acc_tot / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(out_shape).astype(dtype)


def chunked_attention(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)
    v: jax.Array,            # (B, T, K, D)
    q_pos: jax.Array,        # (B, S)
    kv_pos: jax.Array,       # (B, T)  (-1 = empty slot)
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    kv_chunk: int = 1024,
) -> jax.Array:
    """Causal online-softmax attention, scanning KV in chunks."""
    b, s, h, d = q.shape
    m, l, acc = attention_parts(q, k, v, q_pos, kv_pos, window=window,
                                softcap=softcap, kv_chunk=kv_chunk)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, s, h, d).astype(q.dtype)


def attn_forward(
    cfg,
    p: Dict[str, jax.Array],
    x: jax.Array,            # (B, S, M) — post-norm input
    positions: jax.Array,    # (B, S) or (3, B, S)
    kind: str = "attn",
    build_cache: bool = False,
) -> Tuple[jax.Array, Optional[Dict[str, jax.Array]]]:
    """Training / prefill attention over a full sequence."""
    q, k, v = _project_qkv(cfg, p, x)
    q = _rope(cfg, q, positions)
    k = _rope(cfg, k, positions)
    # SP→attention boundary: queries stay sequence-sharded (each shard
    # computes its own rows); K/V gather across the sequence axis here,
    # post-projection and in bf16 — for GQA this moves K·D/M ≈ 8× fewer
    # bytes than gathering the residual stream (§Perf iter C4).
    k = constrain_seq_gathered(k)
    v = constrain_seq_gathered(v)
    pos1 = _pos_1d(positions)
    window = cfg.window if kind in ("attn_local",) or (
        kind == "attn" and cfg.window is not None
    ) else None
    out = chunked_attention(
        q, k, v, pos1, pos1,
        window=window,
        softcap=cfg.attn_logit_softcap,
        kv_chunk=cfg.attn_kv_chunk,
    )
    b, s, _, _ = out.shape
    y = out.reshape(b, s, -1) @ p["wo"].astype(out.dtype)
    cache = None
    if build_cache:
        hot = cfg.decode_hot_len
        nk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
        cdt = k.dtype
        cache = {
            "k": k,
            "v": v,
            "kv_pos": jnp.broadcast_to(pos1, (b, s)).astype(jnp.int32),
            # empty hot ring, filled during decode
            "hk": jnp.zeros((b, hot, nk, hd), cdt),
            "hv": jnp.zeros((b, hot, nk, hd), cdt),
            "h_pos": jnp.full((b, hot), -1, jnp.int32),
        }
    return y, cache


def init_kv_cache(cfg, batch: int, cache_len: int, kind: str,
                  dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    """Split decode cache for one attention layer (paged-attention-style):

      * ``k/v/kv_pos`` — the *prefix*: immutable after prefill, safe to
        shard over the sequence axis (XLA never has to reshard it —
        decode steps only read it);
      * ``hk/hv/h_pos`` — the *hot ring*: a small mutable buffer holding
        freshly decoded tokens, batch-local (never sequence-sharded), so
        the per-step dynamic-update-slice is collective-free.

    A serving layer consolidates hot→prefix every ``decode_hot_len``
    tokens (see ``repro.models.lm.consolidate_caches``); windowed layers
    allocate only ``window`` prefix slots.
    """
    t = cache_len
    if kind == "attn_local" or (kind == "attn" and cfg.window is not None):
        t = min(cache_len, cfg.window)
    hd = cfg.resolved_head_dim
    hot = cfg.decode_hot_len
    return {
        "k": jnp.zeros((batch, t, cfg.num_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, t, cfg.num_kv_heads, hd), dtype),
        "kv_pos": jnp.full((batch, t), -1, jnp.int32),
        "hk": jnp.zeros((batch, hot, cfg.num_kv_heads, hd), dtype),
        "hv": jnp.zeros((batch, hot, cfg.num_kv_heads, hd), dtype),
        "h_pos": jnp.full((batch, hot), -1, jnp.int32),
    }


def _ring_write(cache_arr, new, idx):
    """cache_arr: (B, T, ...); new: (B, 1, ...); idx: (B,) slot index."""
    def upd(c, n, i):
        return jax.lax.dynamic_update_slice(c, n, (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(upd)(cache_arr, new, idx)


def attn_decode(
    cfg,
    p: Dict[str, jax.Array],
    x: jax.Array,            # (B, 1, M) post-norm
    pos: jax.Array,          # (B,) current token position
    cache: Dict[str, jax.Array],
    kind: str = "attn",
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode: write to the hot ring, read prefix + hot ring,
    combine the two partial softmaxes exactly (flash-decoding split)."""
    b = x.shape[0]
    if cfg.mrope_sections is not None:
        positions = jnp.broadcast_to(pos[None, :, None], (3, b, 1))
    else:
        positions = pos[:, None]
    q, k_new, v_new = _project_qkv(cfg, p, x)
    q = _rope(cfg, q, positions)
    k_new = _rope(cfg, k_new, positions)
    hot = cache["hk"].shape[1]
    slot = (pos % hot).astype(jnp.int32)
    cache = dict(cache)
    cache["hk"] = _ring_write(cache["hk"], k_new.astype(cache["hk"].dtype), slot)
    cache["hv"] = _ring_write(cache["hv"], v_new.astype(cache["hv"].dtype), slot)
    cache["h_pos"] = _ring_write(cache["h_pos"],
                                 pos[:, None].astype(jnp.int32), slot)
    window = cfg.window if kind in ("attn_local",) or (
        kind == "attn" and cfg.window is not None
    ) else None
    kw = dict(window=window, softcap=cfg.attn_logit_softcap)
    # Single-shot (kv_chunk = full length): chunking would reshape the
    # sequence-sharded prefix and force XLA to all-gather it; unreshaped,
    # the q·K / softmax / p·V reductions over the sharded axis lower to
    # tiny per-stat all-reduces instead of cache movement.
    parts = [
        attention_parts(
            q, cache["k"], cache["v"], pos[:, None], cache["kv_pos"],
            kv_chunk=cache["k"].shape[1], **kw,
        ),
        attention_parts(
            q, cache["hk"], cache["hv"], pos[:, None], cache["h_pos"],
            kv_chunk=hot, **kw,
        ),
    ]
    out = combine_parts(parts, (b, 1, q.shape[2], q.shape[3]), q.dtype)
    y = out.reshape(b, 1, -1) @ p["wo"].astype(out.dtype)
    return y, cache
