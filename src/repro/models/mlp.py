"""Dense SwiGLU feed-forward block."""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from .common import truncated_normal

__all__ = ["init_mlp_params", "mlp_forward"]


def init_mlp_params(key, cfg) -> Dict[str, jax.Array]:
    m, f = cfg.d_model, cfg.d_ff
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": truncated_normal(k1, (m, f), 1.0, dtype),
        "w_up": truncated_normal(k2, (m, f), 1.0, dtype),
        "w_down": truncated_normal(k3, (f, m), 1.0, dtype),
    }


def mlp_forward(p: Dict[str, jax.Array], x: jax.Array) -> jax.Array:
    dt = x.dtype
    g = jax.nn.silu(x @ p["w_gate"].astype(dt))
    u = x @ p["w_up"].astype(dt)
    return (g * u) @ p["w_down"].astype(dt)
