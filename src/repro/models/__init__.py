from .lm import (
    decode_step,
    init_decode_caches,
    init_params,
    param_count,
    prefill,
    train_loss,
)

__all__ = [
    "decode_step",
    "init_decode_caches",
    "init_params",
    "param_count",
    "prefill",
    "train_loss",
]
