"""Top-k Mixture-of-Experts with GShard-style einsum dispatch/combine.

Tokens are reshaped into dispatch groups of ``moe_group_size``; each
group routes its tokens to ``num_experts_per_token`` experts under a
per-group capacity ``C = ceil(S·k/E · capacity_factor)`` (tokens over
capacity are dropped — the gate weight is zeroed, the residual carries
them). Dispatch/combine are dense one-hot einsums, the standard
TPU-friendly formulation (GShard [arXiv:2006.16668], Switch
[arXiv:2101.03961]): expert parallelism then falls out of sharding the
expert axis of the (g, e, c, m) intermediates over the ``model`` mesh
axis, with XLA inserting the all-to-alls.

Load-balancing auxiliary loss per Switch §2.2 is returned for training.
"""

from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..sharding.act_sharding import current_moe_specs
from .common import truncated_normal

__all__ = ["init_moe_params", "moe_forward", "moe_capacity"]


def _gathered_weight(w: jax.Array, cdt, which: str) -> jax.Array:
    """Cast an expert weight to compute dtype and pin its compute-time
    layout (§Perf iters A3–A5): the FSDP-sharded d_model dim is gathered
    (MB-sized weight shards) instead of letting XLA partial-sum the fat
    (g,e,c,f) activations (tens of GB of all-reduce per layer); the
    expert dim keeps EP (or d_ff keeps TP) per the launcher-provided
    spec. Iteration history: free placement (A3: everything replicated →
    3.7× compute; A4: UNCONSTRAINED → 634 GB all-reduce) — both refuted;
    explicit specs (A5) are the fix."""
    w = w.astype(cdt)
    specs = current_moe_specs()
    if specs is not None:
        spec = specs[0] if which in ("gate", "up") else specs[1]
        if spec is not None:
            w = jax.lax.with_sharding_constraint(w, spec)
    return w


def init_moe_params(key, cfg) -> Dict[str, jax.Array]:
    m, f = cfg.d_model, cfg.moe_d_ff
    e = cfg.num_experts
    ep = cfg.moe_experts_physical   # ≥ e; extra experts are never routed
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": truncated_normal(k1, (m, e), 1.0, dtype),
        "w_gate": truncated_normal(k2, (ep, m, f), 1.0, dtype),
        "w_up": truncated_normal(k3, (ep, m, f), 1.0, dtype),
        "w_down": truncated_normal(k4, (ep, f, m), 1.0, dtype),
    }


def moe_capacity(cfg, group_size: int) -> int:
    c = math.ceil(
        group_size * cfg.num_experts_per_token / cfg.num_experts
        * cfg.capacity_factor
    )
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_forward(
    cfg, p: Dict[str, jax.Array], x: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, M) → (y, aux_loss)."""
    b, s, m = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_token
    ep = cfg.moe_experts_physical   # one-hot width (padded experts are
    #                                 dead: router has no logit for them)
    tokens = b * s
    gs = min(cfg.moe_group_size, tokens)
    while tokens % gs != 0:   # fall back to the largest divisor group
        gs -= 1
    g = tokens // gs
    c = moe_capacity(cfg, gs)
    cdt = x.dtype
    xg = x.reshape(g, gs, m)

    # --- routing (fp32) ---
    logits = (xg @ p["router"].astype(cdt)).astype(jnp.float32)  # (g,gs,e)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)                        # (g,gs,k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # --- capacity assignment: earlier tokens (and lower k) win ---
    eh = jax.nn.one_hot(top_i, ep, dtype=jnp.float32)             # (g,gs,k,ep)
    # flatten (token, k) token-major (the GShard priority) and count
    # earlier assignments to the same expert:
    ehf = eh.reshape(g, gs * k, ep)
    pos = jnp.cumsum(ehf, axis=1) - ehf                           # (g,gs*k,e)
    pos_k = jnp.sum(pos * ehf, axis=-1).reshape(g, gs, k)
    pos_k = pos_k.astype(jnp.int32)                               # (g,gs,k)
    keep = (pos_k < c).astype(jnp.float32)
    gate = top_p * keep

    # dispatch/combine tensors are the fattest MoE intermediates
    # (tokens × E × C) — create them directly in compute dtype
    # (§Perf iter A2: born-fp32 versions double the HBM traffic).
    ch = jax.nn.one_hot(pos_k, c, dtype=cdt)                      # (g,gs,k,c)
    eh_c = eh.astype(cdt)
    dispatch = jnp.einsum("gske,gskc->gsec",
                          eh_c * keep[..., None].astype(cdt), ch)
    combine = jnp.einsum("gske,gskc->gsec",
                         eh_c * gate[..., None].astype(cdt), ch)

    # --- expert computation (compute dtype) ---
    w_gate = _gathered_weight(p["w_gate"], cdt, "gate")    # (e, M, f)
    w_up = _gathered_weight(p["w_up"], cdt, "up")          # (e, M, f)
    w_down = _gathered_weight(p["w_down"], cdt, "down")    # (e, f, M)
    xin = jnp.einsum("gsm,gsec->gecm", xg, dispatch)
    h_gate = jax.nn.silu(jnp.einsum("gecm,emf->gecf", xin, w_gate))
    h_up = jnp.einsum("gecm,emf->gecf", xin, w_up)
    out = jnp.einsum("gecf,efm->gecm", h_gate * h_up, w_down)
    y = jnp.einsum("gecm,gsec->gsm", out, combine)

    # --- Switch load-balance aux loss (over the e *logical* experts) ---
    frac_tokens = jnp.mean(eh[..., :e].sum(2), axis=1)            # (g,e)
    frac_probs = jnp.mean(probs, axis=1)                          # (g,e)
    aux = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, axis=-1))

    return y.reshape(b, s, m), aux
