"""Mamba-2 mixer block (SSD core + projections, causal conv, gated norm).

Layout follows Dao & Gu [arXiv:2405.21060]: separate projections for
z (gate), x, B, C, dt (kept as distinct weights so each shards cleanly —
see sharding/partition.py), a short causal depthwise conv over x/B/C,
the SSD recurrence (via ``repro.kernels.ssd``), a gated RMSNorm and the
output projection. Decode carries (conv tail, SSD state) per layer.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels.ssd import ops as ssd_ops
from ..kernels.ssd.ref import ssd_decode_step
from .common import rms_norm, truncated_normal

__all__ = ["init_ssm_params", "ssm_forward", "init_ssm_cache", "ssm_decode"]


def init_ssm_params(key, cfg) -> Dict[str, jax.Array]:
    m = cfg.d_model
    d_in = cfg.ssm_d_inner
    h = cfg.ssm_heads
    gn = cfg.ssm_groups * cfg.ssm_state
    dc = cfg.ssm_conv
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    return {
        "wz": truncated_normal(ks[0], (m, d_in), 1.0, dtype),
        "wx": truncated_normal(ks[1], (m, d_in), 1.0, dtype),
        "wb": truncated_normal(ks[2], (m, gn), 1.0, dtype),
        "wc": truncated_normal(ks[3], (m, gn), 1.0, dtype),
        "wdt": truncated_normal(ks[4], (m, h), 1.0, dtype),
        "dt_bias": jnp.zeros((h,), dtype),
        "a_log": jnp.zeros((h,), dtype),            # A = -exp(a_log) = -1
        "d_skip": jnp.ones((h,), dtype),
        "conv_x": truncated_normal(ks[5], (dc, d_in), 1.0, dtype),
        "conv_b": truncated_normal(ks[6], (dc, gn), 1.0, dtype),
        "conv_c": truncated_normal(ks[7], (dc, gn), 1.0, dtype),
        "norm": jnp.zeros((d_in,), dtype),
        "wo": truncated_normal(ks[4], (d_in, m), 1.0, dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array,
                 tail: Optional[jax.Array] = None) -> jax.Array:
    """Depthwise causal conv. x: (B, L, C); w: (K, C); tail: (B, K-1, C)
    carries context across calls (decode)."""
    k = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    # windows: out[:, t] = sum_i w[i] * xp[:, t + i]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
    return jax.nn.silu(out)


def _project(cfg, p, h):
    cdt = h.dtype
    z = h @ p["wz"].astype(cdt)
    x = h @ p["wx"].astype(cdt)
    b = h @ p["wb"].astype(cdt)
    c = h @ p["wc"].astype(cdt)
    dt = jax.nn.softplus(
        (h @ p["wdt"].astype(cdt)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )
    return z, x, b, c, dt


def ssm_forward(cfg, p: Dict[str, jax.Array], h: jax.Array,
                build_cache: bool = False):
    """Full-sequence forward. h: (B, L, M) (post-norm input).

    With ``build_cache`` also returns the decode carry (final SSD state +
    conv tails), enabling prefill→decode handoff for SSM layers.
    """
    bsz, l, _ = h.shape
    d_in = cfg.ssm_d_inner
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    k = cfg.ssm_conv
    z, x_raw, b_raw, c_raw, dt = _project(cfg, p, h)
    x = _causal_conv(x_raw, p["conv_x"])
    b = _causal_conv(b_raw, p["conv_b"])
    c = _causal_conv(c_raw, p["conv_c"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    from ..kernels.ssd.ref import ssd_reference

    if build_cache:
        y, state = ssd_reference(
            x.reshape(bsz, l, nh, hp), dt, a,
            b.reshape(bsz, l, g, n), c.reshape(bsz, l, g, n),
            chunk=cfg.ssm_chunk, d_skip=p["d_skip"].astype(jnp.float32),
            return_final_state=True,
        )
    else:
        y = ssd_ops.ssd(
            x.reshape(bsz, l, nh, hp), dt, a,
            b.reshape(bsz, l, g, n), c.reshape(bsz, l, g, n),
            chunk=cfg.ssm_chunk, d_skip=p["d_skip"].astype(jnp.float32),
        )
    y = y.reshape(bsz, l, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["wo"].astype(y.dtype)
    if build_cache:
        cdt = jnp.dtype(cfg.compute_dtype) if hasattr(cfg, "compute_dtype") else x_raw.dtype
        cache = {
            "state": state,
            "conv_x": x_raw[:, -(k - 1):].astype(cdt),
            "conv_b": b_raw[:, -(k - 1):].astype(cdt),
            "conv_c": c_raw[:, -(k - 1):].astype(cdt),
        }
        return out, cache
    return out


def init_ssm_cache(cfg, batch: int, dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    d_in = cfg.ssm_d_inner
    gn = cfg.ssm_groups * cfg.ssm_state
    k = cfg.ssm_conv
    return {
        "state": jnp.zeros(
            (batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        ),
        "conv_x": jnp.zeros((batch, k - 1, d_in), dtype),
        "conv_b": jnp.zeros((batch, k - 1, gn), dtype),
        "conv_c": jnp.zeros((batch, k - 1, gn), dtype),
    }


def ssm_decode(
    cfg, p: Dict[str, jax.Array], h: jax.Array, cache: Dict[str, jax.Array]
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token decode. h: (B, 1, M)."""
    bsz = h.shape[0]
    nh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    g, n = cfg.ssm_groups, cfg.ssm_state
    z, x, b, c, dt = _project(cfg, p, h)
    new_cache = dict(cache)
    outs = {}
    for name, val in (("conv_x", x), ("conv_b", b), ("conv_c", c)):
        tail = cache[name]
        outs[name] = _causal_conv(val, p[name.replace("conv_", "conv_")],
                                  tail=tail)
        new_cache[name] = jnp.concatenate([tail[:, 1:], val.astype(tail.dtype)],
                                          axis=1)
    x, b, c = outs["conv_x"], outs["conv_b"], outs["conv_c"]
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ssd_decode_step(
        x[:, 0].reshape(bsz, nh, hp),
        dt[:, 0],
        a,
        b[:, 0].reshape(bsz, g, n),
        c[:, 0].reshape(bsz, g, n),
        cache["state"],
        d_skip=p["d_skip"].astype(jnp.float32),
    )
    new_cache["state"] = state
    y = y.reshape(bsz, 1, cfg.ssm_d_inner)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["wo"].astype(y.dtype), new_cache
