"""Decoder-only LM assembled from config-driven block patterns.

One generic trunk covers all ten assigned architectures:

  * the layer stack is a ``lax.scan`` over ``cfg.repeats`` repetitions of
    a "super-layer" (``cfg.pattern`` — e.g. ``("attn",)`` for llama,
    ``("attn_local", "attn_global")`` for gemma-2,
    ``("ssm",)*5 + ("shared_attn",)`` for zamba-2) — keeping the HLO
    O(1) in depth and the live activation set bounded (remat policy per
    config);
  * ``shared_attn`` blocks share one parameter set across all scan
    repetitions (Zamba-2) while carrying per-repetition KV caches;
  * frontends: ``token`` (embedding table) or ``embed`` (precomputed
    patch/frame embeddings — the VLM/audio stub per the assignment);
  * losses use chunked cross-entropy (never materializes the full
    (tokens × vocab) logits).

Three entry points map to the assigned shapes: :func:`train_loss`
(train_4k), :func:`prefill` (prefill_32k), :func:`decode_step`
(decode_32k / long_500k serve_step).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..sharding.act_sharding import constrain
from .attention import (
    attn_decode,
    attn_forward,
    init_attn_params,
    init_kv_cache,
)
from .common import chunked_softmax_xent, rms_norm, soft_cap, truncated_normal
from .mlp import init_mlp_params, mlp_forward
from .moe import init_moe_params, moe_forward
from .ssm import init_ssm_cache, init_ssm_params, ssm_decode, ssm_forward

__all__ = [
    "init_params",
    "train_loss",
    "prefill",
    "decode_step",
    "init_decode_caches",
    "param_count",
]

MOE_AUX_WEIGHT = 0.01


def _is_attn(kind: str) -> bool:
    return kind in ("attn", "attn_local", "attn_global", "shared_attn")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_block(cfg, kind: str, key) -> Dict[str, Any]:
    if kind == "ssm":
        k1, k2 = jax.random.split(key)
        return {
            "ln": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
            "ssm": init_ssm_params(k1, cfg),
        }
    k1, k2 = jax.random.split(key)
    p: Dict[str, Any] = {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype)),
        "attn": init_attn_params(k1, cfg),
    }
    if cfg.is_moe:
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype))
        p["moe"] = init_moe_params(k2, cfg)
    elif cfg.d_ff:
        p["ln2"] = jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype))
        p["mlp"] = init_mlp_params(k2, cfg)
    return p


def init_params(cfg, key) -> Dict[str, Any]:
    keys = jax.random.split(key, len(cfg.pattern) + 4)
    params: Dict[str, Any] = {}
    if cfg.frontend == "token":
        params["embed"] = truncated_normal(
            keys[-1], (cfg.padded_vocab, cfg.d_model), 1.0,
            jnp.dtype(cfg.param_dtype),
        )
    slots: Dict[str, Any] = {}
    for i, kind in enumerate(cfg.pattern):
        if kind == "shared_attn":
            continue
        rkeys = jax.random.split(keys[i], cfg.repeats)
        slots[f"slot{i}"] = jax.vmap(
            functools.partial(_init_block, cfg, kind)
        )(rkeys)
    params["slots"] = slots
    if "shared_attn" in cfg.pattern:
        params["shared"] = _init_block(cfg, "shared_attn", keys[-2])
    params["final_norm"] = jnp.zeros((cfg.d_model,), jnp.dtype(cfg.param_dtype))
    params["unembed"] = truncated_normal(
        keys[-3], (cfg.d_model, cfg.padded_vocab), 1.0,
        jnp.dtype(cfg.param_dtype),
    )
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------
def _ffn(cfg, bp, x, aux):
    if cfg.is_moe:
        h = rms_norm(x, bp["ln2"])
        y, a = moe_forward(cfg, bp["moe"], h)
        return x + y, aux + a
    if cfg.d_ff:
        h = rms_norm(x, bp["ln2"])
        return x + mlp_forward(bp["mlp"], h), aux
    return x, aux


def _block_fwd(cfg, kind, bp, x, positions, aux, build_cache):
    """Full-sequence application (train / prefill)."""
    cache = None
    if kind == "ssm":
        h = rms_norm(x, bp["ln"])
        if build_cache:
            y, cache = ssm_forward(cfg, bp["ssm"], h, build_cache=True)
            x = x + y
        else:
            x = x + ssm_forward(cfg, bp["ssm"], h)
    else:
        h = rms_norm(x, bp["ln1"])
        y, cache = attn_forward(cfg, bp["attn"], h, positions, kind,
                                build_cache=build_cache)
        x = x + y
        x, aux = _ffn(cfg, bp, x, aux)
    return x, aux, cache


def _block_decode(cfg, kind, bp, x, pos, cache):
    if kind == "ssm":
        h = rms_norm(x, bp["ln"])
        y, cache = ssm_decode(cfg, bp["ssm"], h, cache)
        return x + y, cache, True
    h = rms_norm(x, bp["ln1"])
    y, cache = attn_decode(cfg, bp["attn"], h, pos, cache, kind)
    x = x + y
    x, _ = _ffn(cfg, bp, x, 0.0)
    return x, cache, False


# ---------------------------------------------------------------------------
# stack (scan over repeats)
# ---------------------------------------------------------------------------
def _stack_fwd(cfg, params, x, positions, build_cache=False):
    shared = params.get("shared")

    def body(carry, xs):
        x, aux = carry
        slot_rows = xs
        caches = {}
        x = constrain(x)   # layer-boundary activation sharding (SP)
        for i, kind in enumerate(cfg.pattern):
            bp = shared if kind == "shared_attn" else slot_rows[f"slot{i}"]
            x, aux, cache = _block_fwd(cfg, kind, bp, x, positions, aux,
                                       build_cache)
            if build_cache and cache is not None:
                caches[f"slot{i}"] = cache
        x = constrain(x)
        return (x, aux), (caches if build_cache else None)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    if getattr(cfg, "scan_layers", True):
        (x, aux), caches = jax.lax.scan(
            body, (x, jnp.float32(0.0)), params["slots"]
        )
        return x, aux, caches
    # unrolled path (dry-run cost calibration; also useful on small R)
    carry = (x, jnp.float32(0.0))
    cache_rows = []
    for r in range(cfg.repeats):
        rows = jax.tree.map(lambda a: a[r], params["slots"])
        carry, cache_r = body(carry, rows)
        if build_cache:
            cache_rows.append(cache_r)
    x, aux = carry
    caches = (
        jax.tree.map(lambda *xs: jnp.stack(xs), *cache_rows)
        if build_cache and cache_rows
        else None
    )
    return x, aux, caches


def _stack_decode(cfg, params, x, pos, caches):
    shared = params.get("shared")

    def body(carry, xs):
        x = carry
        slot_rows, cache_rows = xs
        new_caches = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"slot{i}"
            bp = shared if kind == "shared_attn" else slot_rows[key]
            x, new_c, _ = _block_decode(cfg, kind, bp, x, pos,
                                        cache_rows[key])
            new_caches[key] = new_c
        return x, new_caches

    if getattr(cfg, "scan_layers", True):
        x, new_caches = jax.lax.scan(body, x, (params["slots"], caches))
        return x, new_caches
    cache_rows_out = []
    for r in range(cfg.repeats):
        rows = jax.tree.map(lambda a: a[r], params["slots"])
        cache_r = jax.tree.map(lambda a: a[r], caches)
        x, new_c = body(x, (rows, cache_r))
        cache_rows_out.append(new_c)
    new_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *cache_rows_out)
    return x, new_caches


# ---------------------------------------------------------------------------
# frontends / positions
# ---------------------------------------------------------------------------
def _embed(cfg, params, inputs):
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "token":
        table = params["embed"].astype(cdt)
        if getattr(cfg, "embed_onehot", True):
            # one-hot matmul: lowers to an MXU dot that partitions
            # cleanly over a sharded vocab (XLA fuses the iota-compare
            # one-hot); the plain gather was lowered as an fp32
            # mask-and-psum over vocab shards (§Perf iter C5).
            b, s = inputs.shape
            flat = inputs.reshape(-1)
            oh = jax.nn.one_hot(flat, table.shape[0], dtype=cdt)
            return (oh @ table).reshape(b, s, -1)
        return jnp.take(table, inputs, axis=0)
    return inputs.astype(cdt)  # precomputed embeddings (VLM/audio stub)


def _positions(cfg, batch: int, seq: int):
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos, (3, batch, seq))
    return pos


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------
def train_loss(cfg, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """batch: {"inputs": (B,S) int32 or (B,S,M) embeds, "labels": (B,S)}."""
    inputs, labels = batch["inputs"], batch["labels"]
    b, s = labels.shape
    x = _embed(cfg, params, inputs)
    x, aux, _ = _stack_fwd(cfg, params, x, _positions(cfg, b, s))
    h = rms_norm(x, params["final_norm"])
    loss_sum, count = chunked_softmax_xent(
        h.reshape(-1, cfg.d_model),
        params["unembed"],
        labels.reshape(-1),
        chunk=cfg.loss_chunk,
        final_softcap=cfg.final_logit_softcap,
    )
    loss = loss_sum / jnp.maximum(count, 1.0)
    metrics = {"loss": loss, "tokens": count}
    if cfg.is_moe:
        metrics["moe_aux"] = aux
        loss = loss + MOE_AUX_WEIGHT * aux
    return loss, metrics


def _logits(cfg, params, h):
    out = (h @ params["unembed"].astype(h.dtype)).astype(jnp.float32)
    return soft_cap(out, cfg.final_logit_softcap)


def prefill(cfg, params, inputs) -> Tuple[jax.Array, Any, jax.Array]:
    """Full-sequence prefill; returns (last-token logits, caches, pos)."""
    if inputs.ndim == 2:
        b, s = inputs.shape
    else:
        b, s = inputs.shape[0], inputs.shape[1]
    x = _embed(cfg, params, inputs)
    x, _, caches = _stack_fwd(cfg, params, x, _positions(cfg, b, s),
                              build_cache=True)
    h = rms_norm(x[:, -1:], params["final_norm"])
    pos = jnp.full((b,), s, jnp.int32)
    return _logits(cfg, params, h)[:, 0], caches, pos


def init_decode_caches(cfg, batch: int, cache_len: int, filled: bool = False):
    """Stacked (R-leading) cache pytree for decoding.

    ``filled=True`` marks every slot as holding real tokens (emulating a
    cache after ``cache_len`` tokens of prefill) — the decode dry-run
    shapes use this.
    """
    caches: Dict[str, Any] = {}
    r = cfg.repeats
    dtype = jnp.dtype(cfg.compute_dtype)

    def stack(tree):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (r,) + x.shape), tree)

    for i, kind in enumerate(cfg.pattern):
        if kind == "ssm":
            caches[f"slot{i}"] = stack(init_ssm_cache(cfg, batch, dtype))
        elif _is_attn(kind):
            c = init_kv_cache(cfg, batch, cache_len, kind, dtype)
            if filled:
                t = c["kv_pos"].shape[1]
                c["kv_pos"] = jnp.broadcast_to(
                    jnp.arange(cache_len - t, cache_len, dtype=jnp.int32),
                    (batch, t),
                )
            caches[f"slot{i}"] = stack(c)
    return caches


def grow_caches(cfg, caches, new_len: int):
    """Extend prefill caches to ``new_len`` slots for decoding (windowed
    layers cap at their window). Ring indexing then continues writing at
    ``pos % T`` without evicting live context."""
    out = {}
    for i, kind in enumerate(cfg.pattern):
        key = f"slot{i}"
        if key not in caches:
            continue
        c = caches[key]
        if kind == "ssm":
            out[key] = c
            continue
        t_new = new_len
        if kind == "attn_local" or (kind == "attn" and cfg.window is not None):
            t_new = min(new_len, cfg.window)
        t_cur = c["k"].shape[2]  # stacked: (R, B, T, K, D)
        if t_new <= t_cur:
            out[key] = c
            continue
        pad = t_new - t_cur
        grown = dict(c)  # hot-ring keys pass through untouched
        grown["k"] = jnp.pad(c["k"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        grown["v"] = jnp.pad(c["v"], ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        grown["kv_pos"] = jnp.pad(c["kv_pos"], ((0, 0), (0, 0), (0, pad)),
                                  constant_values=-1)
        out[key] = grown
    return out


def consolidate_caches(cfg, caches):
    """Flush hot-ring entries into the prefix cache (amortized every
    ``decode_hot_len`` tokens by the serving layer) and reset the rings.
    Prefix writes use ring semantics (slot = pos % T) with out-of-range
    drops, so windowed and full layers share the path."""
    out = {}
    for i, kind in enumerate(cfg.pattern):
        key = f"slot{i}"
        if key not in caches:
            continue
        c = caches[key]
        if kind == "ssm" or "hk" not in c:
            out[key] = c
            continue
        t = c["k"].shape[2]

        def flush(pk, pv, ppos, hk, hv, hpos):
            # per (repeat, batch) row: scatter valid hot slots into prefix
            valid = hpos >= 0
            idx = jnp.where(valid, hpos % t, t)   # t = out of range → drop
            pk = pk.at[idx].set(hk, mode="drop")
            pv = pv.at[idx].set(hv, mode="drop")
            ppos = ppos.at[idx].set(hpos, mode="drop")
            return pk, pv, ppos

        pk, pv, ppos = jax.vmap(jax.vmap(flush))(
            c["k"], c["v"], c["kv_pos"], c["hk"], c["hv"], c["h_pos"]
        )
        out[key] = {
            "k": pk, "v": pv, "kv_pos": ppos,
            "hk": jnp.zeros_like(c["hk"]),
            "hv": jnp.zeros_like(c["hv"]),
            "h_pos": jnp.full_like(c["h_pos"], -1),
        }
    return out


def decode_step(cfg, params, token, pos, caches):
    """One-token serve step. token: (B,1) int32 (or (B,1,M) embeds);
    pos: (B,) tokens decoded so far. Returns (logits (B,V), new caches,
    pos+1)."""
    x = _embed(cfg, params, token)
    x, new_caches = _stack_decode(cfg, params, x, pos, caches)
    h = rms_norm(x, params["final_norm"])
    return _logits(cfg, params, h)[:, 0], new_caches, pos + 1
