"""Shared neural building blocks: norms, initializers, rotary embeddings
(standard + multimodal M-RoPE), logit soft-capping, chunked cross-entropy.

All modules are pure functions over explicit parameter pytrees (dicts of
jnp arrays). Parameters are stored in ``param_dtype`` (fp32 master by
default) and cast to ``compute_dtype`` (bf16) at use — the MaxText-style
mixed-precision convention.
"""

from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "truncated_normal",
    "rms_norm",
    "soft_cap",
    "rope_frequencies",
    "apply_rope",
    "apply_mrope",
    "chunked_softmax_xent",
]


def truncated_normal(key, shape, scale: float, dtype=jnp.float32):
    """Fan-in scaled truncated-normal initializer."""
    stddev = scale / math.sqrt(max(1, shape[0]))
    return stddev * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm with fp32 accumulation; returns x.dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def soft_cap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings, shape (head_dim//2,)."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def _rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    """Apply rotation given per-(pos, half-dim) angles.

    x: (..., S, H, D); angles: broadcastable to (..., S, 1, D/2).
    """
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float = 10000.0
) -> jax.Array:
    """Standard RoPE. x: (B, S, H, D); positions: (B, S) int."""
    freqs = rope_frequencies(x.shape[-1], theta)          # (D/2,)
    angles = positions[..., None, None].astype(jnp.float32) * freqs  # (B,S,1,D/2)
    return _rotate(x, angles)


def apply_mrope(
    x: jax.Array,
    positions: jax.Array,
    sections: Sequence[int],
    theta: float = 10000.0,
) -> jax.Array:
    """Qwen2-VL multimodal rotary embedding (M-RoPE).

    The half-dim frequency bands are split into ``sections`` (e.g.
    (16, 24, 24) = temporal/height/width for D=128) and each section
    rotates by its own position stream. ``positions``: (3, B, S).
    For text tokens all three streams coincide (the stub frontend
    supplies arange for each).
    """
    half = x.shape[-1] // 2
    if sum(sections) != half:
        raise ValueError(f"mrope sections {sections} must sum to {half}")
    freqs = rope_frequencies(x.shape[-1], theta)          # (half,)
    # Build per-band position selector: band i uses positions[stream(i)]
    stream_idx = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=half
    )                                                      # (half,)
    pos = positions.astype(jnp.float32)                    # (3, B, S)
    pos_per_band = jnp.take(pos, stream_idx, axis=0)       # (half, B, S)
    pos_per_band = jnp.moveaxis(pos_per_band, 0, -1)       # (B, S, half)
    angles = pos_per_band[..., None, :] * freqs            # (B, S, 1, half)
    return _rotate(x, angles)


def chunked_softmax_xent(
    hidden: jax.Array,
    unembed: jax.Array,
    labels: jax.Array,
    chunk: int = 16384,
    final_softcap: Optional[float] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Cross-entropy over a large vocabulary without materializing the
    full (tokens, vocab) logits tensor.

    hidden: (T, M); unembed: (M, V); labels: (T,) int32 (-1 = masked).
    Scans over token chunks; per-chunk logits are fp32. Returns
    (sum_loss, token_count). Gradients flow through the scan.
    """
    t = hidden.shape[0]
    if t % chunk != 0:
        # pad to a multiple; padded tokens are masked out
        pad = chunk - t % chunk
        hidden = jnp.pad(hidden, ((0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, pad),), constant_values=-1)
        t = hidden.shape[0]
    n_chunks = t // chunk
    hidden = hidden.reshape(n_chunks, chunk, hidden.shape[-1])
    labels = labels.reshape(n_chunks, chunk)

    def body(acc, xs):
        h, y = xs
        logits = (h @ unembed.astype(h.dtype)).astype(jnp.float32)
        logits = soft_cap(logits, final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(
            logits, jnp.maximum(y, 0)[:, None], axis=-1
        )[:, 0]
        mask = (y >= 0).astype(jnp.float32)
        loss = jnp.sum((lse - picked) * mask)
        count = jnp.sum(mask)
        return (acc[0] + loss, acc[1] + count), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hidden, labels)
    )
    return loss_sum, count
