"""Step-function factories + abstract input specs for every assigned
(architecture × shape) cell.

``input_specs(cfg, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins for every model input — shardable, no device allocation — the
dry-run lowers against these. Train cells lower ``train_step`` (fwd +
bwd + AdamW update); prefill cells lower ``prefill_step``; decode cells
lower ``serve_step`` (one new token against a seq_len cache).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..models import lm
from ..optim.adamw import AdamWConfig, adamw_update, init_opt_state

__all__ = [
    "init_train_state",
    "train_state_shapes",
    "make_train_step",
    "make_prefill_step",
    "make_serve_step",
    "input_specs",
    "serve_params_shapes",
    "model_flops",
]


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------
def init_train_state(cfg: ModelConfig, key) -> Dict[str, Any]:
    params = lm.init_params(cfg, key)
    return {
        "params": params,
        "opt": init_opt_state(params),
        "step": jnp.zeros((), jnp.int32),
    }


def train_state_shapes(cfg: ModelConfig):
    return jax.eval_shape(
        functools.partial(init_train_state, cfg), jax.random.PRNGKey(0)
    )


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig = AdamWConfig()):
    cdt = jnp.dtype(cfg.compute_dtype)

    def cast_params(p):
        # One bf16 cast per step OUTSIDE the layer scan: FSDP weight
        # all-gathers then move bf16 shards, not fp32 masters (§Perf
        # iter C2 — halves the dominant all-gather bytes). fp32 masters
        # are touched only by the optimizer.
        return jax.tree.map(
            lambda x: x.astype(cdt)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            p,
        )

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: lm.train_loss(cfg, cast_params(p), batch),
            has_aux=True,
        )(state["params"])
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, state["params"], grads, state["opt"]
        )
        new_state = {
            "params": new_params,
            "opt": new_opt,
            "step": state["step"] + 1,
        }
        return new_state, {**metrics, **opt_metrics}

    return train_step


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------
def serve_params_shapes(cfg: ModelConfig):
    """Serving weights are bf16 (fp32 masters live in the train state)."""
    shapes = jax.eval_shape(
        functools.partial(lm.init_params, cfg), jax.random.PRNGKey(0)
    )
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape,
            jnp.bfloat16 if jnp.issubdtype(s.dtype, jnp.floating) else s.dtype,
        ),
        shapes,
    )


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, inputs):
        return lm.prefill(cfg, params, inputs)

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, token, pos, caches):
        return lm.decode_step(cfg, params, token, pos, caches)

    return serve_step


# ---------------------------------------------------------------------------
# abstract input specs
# ---------------------------------------------------------------------------
def _token_spec(cfg: ModelConfig, batch: int, seq: int):
    if cfg.frontend == "token":
        return jax.ShapeDtypeStruct((batch, seq), jnp.int32)
    # VLM/audio stub: precomputed frame/patch embeddings
    return jax.ShapeDtypeStruct(
        (batch, seq, cfg.d_model), jnp.dtype(cfg.compute_dtype)
    )


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[Any, ...]:
    """Abstract inputs for the step the shape lowers (excl. params/state)."""
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        batch = {
            "inputs": _token_spec(cfg, b, s),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
        }
        return (batch,)
    if shape.kind == "prefill":
        return (_token_spec(cfg, b, s),)
    if shape.kind == "decode":
        token = _token_spec(cfg, b, 1)
        pos = jax.ShapeDtypeStruct((b,), jnp.int32)
        caches = jax.eval_shape(
            functools.partial(
                lm.init_decode_caches, cfg, b, s, filled=True
            )
        )
        return (token, pos, caches)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# model FLOPs accounting (roofline §"useful" numerator)
# ---------------------------------------------------------------------------
def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """6·N·tokens for training (fwd+bwd), 2·N·tokens for inference
    forward passes (decode: one token per sequence). N = active params
    contributing matmul FLOPs (embedding-gather excluded)."""
    n = cfg.n_flops_params()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: 1 new token
