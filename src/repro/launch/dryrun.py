import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes and extract the roofline terms.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run (and only the dry-run) needs 512 placeholder host
devices so ``jax.make_mesh`` can build the 16×16 and 2×16×16 meshes.

Per cell this:
  1. builds abstract inputs (ShapeDtypeStruct — no allocation),
  2. ``jax.jit(step, in_shardings=…).lower(...).compile()`` under the mesh,
  3. prints ``compiled.memory_analysis()`` (fits-HBM proof) and
     ``cost_analysis()`` (FLOPs/bytes for §Roofline),
  4. scans the post-SPMD HLO for collective bytes,
  5. emits the roofline report + the TALP analytical device metrics
     (the paper's Device PE tree, *predicted* for this mesh) as JSON.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config, list_configs
from ..core.analysis import analyze_trace
from ..core.backends.analytical import StepModel, trace_from_step_model
from ..roofline.analysis import build_report, collective_bytes_from_hlo
from ..sharding.act_sharding import activation_sharding, moe_weight_sharding
from ..sharding.partition import (
    batch_pspec,
    cache_pspec,
    fsdp_axes,
    make_sharding_tree,
    param_pspec,
    state_shardings,
)
from .mesh import describe_mesh, make_production_mesh
from .steps import (
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    model_flops,
    serve_params_shapes,
    train_state_shapes,
)


def _in_shardings(cfg, shape, mesh, specs):
    from jax.sharding import NamedSharding

    def batch_shard(tree):
        return jax.tree.map(
            lambda s: NamedSharding(
                mesh, batch_pspec(mesh, s.shape[0], s.ndim)
            ),
            tree,
        )

    if shape.kind == "train":
        state = state_shardings(train_state_shapes(cfg), mesh, cfg)
        return (state, batch_shard(specs[0]))
    params = make_sharding_tree(serve_params_shapes(cfg), mesh, cfg,
                                param_pspec)
    if shape.kind == "prefill":
        return (params, batch_shard(specs[0]))
    token, pos, caches = specs
    cache_sh = make_sharding_tree(caches, mesh, cfg, cache_pspec)
    return (params, batch_shard(token), batch_shard(pos), cache_sh)


def _act_spec(cfg, shape, mesh):
    """Layer-boundary activation sharding: batch over FSDP, sequence over
    the model axis (SP) when divisible. Decode steps (S=1) skip it."""
    if shape.kind == "decode":
        return None
    if shape.seq_len % mesh.shape["model"] != 0:
        return None
    fsdp = fsdp_axes(mesh)
    b_ax = fsdp if shape.global_batch % _axsize(mesh, fsdp) == 0 else None
    return P(b_ax, "model", None)


def _axsize(mesh, axes):
    import numpy as np
    return int(np.prod([mesh.shape[a] for a in axes])) if axes else 1


def _moe_specs(cfg, mesh):
    """Compute-time MoE weight layout (§Perf A5): expert-parallel over
    ``model`` when E divides it, else TP over d_ff; the FSDP d_model dim
    is always gathered."""
    if not cfg.is_moe:
        return (None, None)
    if cfg.moe_experts_physical % mesh.shape["model"] == 0:
        return (P("model", None, None), P("model", None, None))
    if cfg.moe_d_ff % mesh.shape["model"] == 0:
        return (P(None, None, "model"), P(None, "model", None))
    return (P(), P())


def _compile_cell(cfg, shape, mesh):
    """Lower + compile one cell under the mesh; returns timings too."""
    from jax.sharding import NamedSharding

    specs = input_specs(cfg, shape)
    shardings = _in_shardings(cfg, shape, mesh, specs)
    out_shardings = None
    if shape.kind == "train":
        step = make_train_step(cfg)
        args = (train_state_shapes(cfg),) + specs
        donate = (0,)
        out_shardings = (shardings[0], None)  # new state keeps its layout
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg)
        args = (serve_params_shapes(cfg),) + specs
        donate = ()
    else:
        step = make_serve_step(cfg)
        args = (serve_params_shapes(cfg),) + specs
        donate = (3,)
        # logits stay vocab-sharded (sampling is shard-local + argmax
        # exchange, never an all-gather of (B, V)); caches keep their
        # input layout; pos replicated.
        # Iteration B3 (refuted, see EXPERIMENTS.md §Perf): pinning decode
        # output shardings (logits vocab-sharded and/or cache out == in)
        # INCREASED collective bytes — XLA's inferred placements for the
        # donated caches are already copy-free, and forcing layouts makes
        # it reshard the hidden state. Leave decode outputs unpinned.
        out_shardings = None
    t0 = time.time()
    gate_up, down = _moe_specs(cfg, mesh)
    with mesh, activation_sharding(_act_spec(cfg, shape, mesh)), \
            moe_weight_sharding(gate_up, down):
        jitted = jax.jit(step, in_shardings=shardings,
                         out_shardings=out_shardings,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    return compiled, t_lower, t_compile


def _cost_triplet(compiled):
    """(flops, hbm bytes, collective-bytes-by-kind) of a compiled module."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    stats = collective_bytes_from_hlo(compiled.as_text())
    return (
        float(cost.get("flops", 0.0)),
        float(cost.get("bytes accessed", 0.0)),
        dict(stats.bytes_by_kind),
        dict(stats.count_by_kind),
    )


def _calibrated_cost(cfg, shape, mesh):
    """XLA's cost analysis counts a scan body ONCE regardless of trip
    count (calibrated in tests/test_roofline_calibration.py), so per-cell
    roofline terms come from unrolled R=1 / R=2 compiles extrapolated
    linearly in depth — exact for these homogeneous stacks:
        total(R) = m1 + (R - 1) · (m2 - m1).
    """
    period = len(cfg.pattern)
    r = cfg.repeats
    cfg1 = dataclasses.replace(cfg, num_layers=period, scan_layers=False)
    cfg2 = dataclasses.replace(cfg, num_layers=2 * period, scan_layers=False)
    c1, *_ = _compile_cell(cfg1, shape, mesh)
    f1, b1, coll1, cnt1 = _cost_triplet(c1)
    if r == 1:
        return f1, b1, coll1, cnt1
    c2, *_ = _compile_cell(cfg2, shape, mesh)
    f2, b2, coll2, cnt2 = _cost_triplet(c2)

    def extrap(m1, m2):
        return m1 + (r - 1) * max(0.0, m2 - m1)

    kinds = set(coll1) | set(coll2)
    coll = {k: int(extrap(coll1.get(k, 0), coll2.get(k, 0))) for k in kinds}
    cnt = {k: int(extrap(cnt1.get(k, 0), cnt2.get(k, 0))) for k in kinds}
    return extrap(f1, f2), extrap(b1, b2), coll, cnt


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             out_dir: str = None, verbose: bool = True,
             arch_overrides: dict = None, calibrate: bool = True):
    cfg = get_config(arch)
    if arch_overrides:
        cfg = dataclasses.replace(cfg, **arch_overrides)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.long_context_ok:
        return {
            "arch": arch, "shape": shape_name,
            "status": "skipped",
            "reason": "pure full attention at every layer (DESIGN.md "
                      "long_500k skip policy)",
        }
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_desc = describe_mesh(mesh)
    chips = mesh.devices.size

    # 1) production compile (scan stack) — the coherence proof + memory
    compiled, t_lower, t_compile = _compile_cell(cfg, shape, mesh)
    try:
        mem = compiled.memory_analysis()
    except Exception:
        mem = None
    raw_flops, raw_bytes, raw_coll, raw_cnt = _cost_triplet(compiled)

    # 2) depth-calibrated roofline terms (single-pod analysis passes;
    #    the multi-pod sweep is the compile-coherence proof only)
    if calibrate:
        flops, hbm_bytes, coll, coll_cnt = _calibrated_cost(cfg, shape, mesh)
    else:
        flops, hbm_bytes, coll, coll_cnt = (
            raw_flops, raw_bytes, raw_coll, raw_cnt
        )

    report = build_report(
        arch=arch, shape=shape_name, mesh_desc=mesh_desc, chips=chips,
        cost={"flops": flops, "bytes accessed": hbm_bytes},
        hlo_text="",
        model_flops_global=model_flops(cfg, shape),
        memory_analysis=mem,
    )
    report.collective_bytes = float(sum(coll.values()))
    report.collective_detail = coll
    report.collective_count = sum(coll_cnt.values())

    # TALP analytical device metrics (paper eqs. 9–12 predicted for this
    # mesh) + the beyond-paper Computational Efficiency branch.
    sm = StepModel(
        flops=report.flops,
        hbm_bytes=report.hbm_bytes,
        collective_bytes=report.collective_bytes,
        model_flops=report.model_flops,
    )
    talp = analyze_trace(
        trace_from_step_model([sm], steps=1),
        computational_efficiency=sm.computational_efficiency,
    )

    result = {
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        **report.to_dict(),
        "raw_scan_cost": {   # uncalibrated (scan body counted once)
            "flops": raw_flops,
            "hbm_bytes": raw_bytes,
            "collective_bytes": raw_coll,
        },
        "memory_analysis": {
            "peak_memory": report.peak_memory,
            "argument_size": report.argument_size,
            "output_size": report.output_size,
            "temp_size": report.temp_size,
        },
        "talp_device": talp.device.as_dict() if talp.device else None,
    }

    if verbose:
        print(f"=== {arch} × {shape_name} × {mesh_desc} ===")
        if mem is not None:
            print(f"memory_analysis: {mem}")
        print(f"calibrated: flops={flops:.3e} hbm_bytes={hbm_bytes:.3e}")
        print(
            f"roofline: compute={report.compute_s*1e3:.3f}ms "
            f"memory={report.memory_s*1e3:.3f}ms "
            f"collective={report.collective_s*1e3:.3f}ms "
            f"dominant={report.dominant} "
            f"fraction={report.roofline_fraction:.3f} "
            f"useful_ratio={report.useful_flop_ratio:.3f}"
        )
        print(f"collectives: {report.collective_detail}")
        sys.stdout.flush()

    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        tag = f"{arch}__{shape_name}__{mesh_desc}".replace("/", "_")
        with open(os.path.join(out_dir, tag + ".json"), "w") as f:
            json.dump(result, f, indent=2)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs())
    ap.add_argument("--shape", choices=sorted(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="every (arch × shape) cell on this mesh")
    ap.add_argument("--no-calibrate", action="store_true",
                    help="skip the R=1/R=2 depth-calibration compiles")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in list_configs():
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    failures = 0
    for arch, shape in cells:
        try:
            res = run_cell(arch, shape, multi_pod=args.multi_pod,
                           out_dir=args.out,
                           calibrate=not args.no_calibrate)
            if res["status"] == "skipped":
                print(f"--- {arch} × {shape}: SKIPPED ({res['reason']})")
        except Exception:
            failures += 1
            print(f"!!! {arch} × {shape}: FAILED")
            traceback.print_exc()
        sys.stdout.flush()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
