"""Batched serving driver: prefill + decode loop under TALP monitoring.

Requests are prompt batches; the loop prefills the batch, grows the
caches, then decodes tokens autoregressively. Host/device states are
TALP-monitored exactly as in training — the serving profile typically
shows high Offload (host blocked on decode steps) and the per-step
Orchestration gap, which is the paper's framing for "the host cannot
feed the device."

Usage (CPU-sized):
  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --requests 4 --prompt-len 32 --gen-len 16
"""

from __future__ import annotations

import argparse
import sys
import time
from contextlib import nullcontext

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ShapeConfig, get_config, list_configs, smoke_config
from ..core.backends import RuntimeBackend
from ..core.merge import FileSpoolTransport, emit_job_report
from ..core.report import render_tables, to_json
from ..core.talp import TalpMonitor
from ..models import lm
from .steps import make_prefill_step, make_serve_step, model_flops

__all__ = ["serve", "main"]


def serve(
    cfg,
    requests: int = 4,
    prompt_len: int = 32,
    gen_len: int = 16,
    seed: int = 0,
    talp_json: str = None,
    verbose: bool = True,
    rank: int = 0,
    world_size: int = 1,
    talp_spool: str = None,
    talp_sample_every: int = 0,
    talp_spool_format: str = "binary",
    talp_trace_out: str = None,
    talp_metrics_jsonl: str = None,
    talp_prometheus_port: int = None,
    talp_step_series: int = 0,
    talp_watchdog: bool = False,
    talp_anomaly_log: str = None,
    talp_fault_plan=None,
):
    """Serve a batch of requests. Multi-rank serving fleets: pass
    ``rank``/``world_size`` and a shared ``talp_spool`` dir to get one
    job-level TALP report across all serving processes.
    ``talp_sample_every=N`` publishes a mid-run snapshot every N decoded
    tokens (merged across ranks when a spool is given).

    Observability mirrors :func:`repro.launch.train.train`:
    ``talp_trace_out`` (Chrome/Perfetto trace at exit),
    ``talp_metrics_jsonl`` (snapshot stream), ``talp_prometheus_port``
    (opt-in ``/metrics`` endpoint — the natural fit for a long-lived
    serving process). ``talp_step_series``/``talp_watchdog``/
    ``talp_anomaly_log`` mirror the training driver at decode-token
    resolution: each decode iteration runs in a nested ``decode_step``
    region whose close feeds the per-step ring and the anomaly
    watchdog. The decode-shape FLOP estimate feeds the measured
    Computational Efficiency annotation. ``talp_fault_plan`` injects
    deterministic collection faults for this rank (debug) — see
    :class:`repro.core.collect.FaultPlan`."""
    from ..core.collect import FaultPlan

    fault_plan = (FaultPlan.from_spec(talp_fault_plan)
                  if talp_fault_plan is not None else None)
    clock = time.perf_counter
    if fault_plan is not None:
        skew = fault_plan.skew_s(rank)
        if skew:
            clock = lambda: time.perf_counter() + skew  # noqa: E731
        if verbose and fault_plan.touches(rank):
            print(f"[talp fault] rank {rank} plan: "
                  f"{fault_plan.describe(rank)}")
    backend = RuntimeBackend()
    want_steps = bool(talp_step_series or talp_watchdog or talp_anomaly_log)
    flop_model = None
    if want_steps:
        from ..core.backends.analytical import StepModel

        shape = ShapeConfig(name="serve", seq_len=prompt_len + gen_len,
                            global_batch=requests, kind="decode")
        flop_model = StepModel(
            flops=0.0, hbm_bytes=0.0, collective_bytes=0.0,
            model_flops=model_flops(cfg, shape) / max(world_size, 1),
        )
    mon = TalpMonitor("serve", rank=rank, clock=clock, backend=backend,
                      overhead_report=True, flop_model=flop_model)
    step_recorder = step_watchdog = None
    if want_steps:
        from ..core.telemetry.stepseries import StepSeriesRecorder

        if talp_watchdog or talp_anomaly_log:
            from ..core.telemetry.watchdog import EfficiencyWatchdog

            step_watchdog = EfficiencyWatchdog(jsonl=talp_anomaly_log)
        step_recorder = StepSeriesRecorder(
            mon, capacity=talp_step_series or 4096,
            regions=("decode_step",), watchdog=step_watchdog,
        )
    sample_transport = (
        FileSpoolTransport(talp_spool, world_size=world_size,
                           payload=talp_spool_format)
        if talp_spool and talp_sample_every else None
    )
    telemetry = None
    if talp_metrics_jsonl or talp_prometheus_port is not None or talp_trace_out:
        from ..core.telemetry.exporter import TelemetryExporter

        telemetry = TelemetryExporter(mon, jsonl=talp_metrics_jsonl,
                                      watchdog=step_watchdog)
        if talp_prometheus_port is not None:
            port = telemetry.serve(port=talp_prometheus_port)
            if verbose:
                print(f"[talp] prometheus exposition on :{port}/metrics")

    def sample_snapshot(tag: str) -> None:
        snapshot = (
            telemetry.sample().result if telemetry is not None
            else mon.sample_result()
        )
        if sample_transport is not None:
            sample_transport.submit_sample(snapshot, rank=rank)
            job_snap = sample_transport.merge_samples(name=mon.name)
        else:
            job_snap = snapshot
        if verbose:
            g = job_snap.regions.get(TalpMonitor.GLOBAL)
            if g is not None and g.host is not None:
                print(f"[talp sample] {tag} "
                      f"ranks={g.n_ranks} devices={g.n_devices} "
                      f"PE_host={g.host.parallel_efficiency:.3f}")
    key = jax.random.PRNGKey(seed)

    with mon.region("init"):
        params = lm.init_params(cfg, key)
        params = jax.tree.map(
            lambda x: x.astype(jnp.bfloat16)
            if jnp.issubdtype(x.dtype, jnp.floating) else x,
            params,
        )
        params = jax.block_until_ready(params)

    prefill_fn = jax.jit(make_prefill_step(cfg))
    decode_fn = jax.jit(make_serve_step(cfg), donate_argnums=3)

    if cfg.frontend == "token":
        prompts = jax.random.randint(
            key, (requests, prompt_len), 0, cfg.vocab_size, jnp.int32
        )
    else:
        prompts = jax.random.normal(
            key, (requests, prompt_len, cfg.d_model), jnp.bfloat16
        )

    tokens_out = []
    with mon.region("prefill"):
        h = backend.launch(prefill_fn, params, prompts, name="prefill")
        with mon.offload():
            logits, caches, pos = backend.wait(h)
    with mon.region("grow_cache"):
        caches = lm.grow_caches(cfg, caches, prompt_len + gen_len)

    tok = jnp.argmax(logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
    with mon.region("decode"):
        for t in range(gen_len):
            with (mon.region("decode_step") if step_recorder is not None
                  else nullcontext()):
                tokens_out.append(np.asarray(tok))
                if cfg.frontend == "token":
                    inp = tok[:, None]
                else:  # embed-frontend stub: feed a frame embedding
                    inp = jnp.zeros((requests, 1, cfg.d_model), jnp.bfloat16)
                h = backend.launch(decode_fn, params, inp, pos, caches,
                                   name=f"decode_{t}")
                with mon.offload():
                    logits, caches, pos = backend.wait(h)
                tok = jnp.argmax(
                    logits[:, : cfg.vocab_size], -1).astype(jnp.int32)
            if talp_sample_every and (t + 1) % talp_sample_every == 0:
                sample_snapshot(f"token {t}")

    if telemetry is not None:
        telemetry.sample()  # last stream record covers the full window
    if step_recorder is not None:
        step_recorder.close()   # detach before finalize's Global close
    result = mon.finalize()
    if talp_trace_out:
        from ..core.telemetry.traceexport import export_monitor

        with open(talp_trace_out, "w") as f:
            f.write(export_monitor(
                mon, result=result,
                samples=telemetry.trace_samples() if telemetry else None,
                step_series=(step_recorder.series
                             if step_recorder is not None else None),
                anomalies=(step_watchdog.events
                           if step_watchdog is not None else None),
            ))
        if verbose:
            print(f"[talp] wrote Chrome trace: {talp_trace_out}")
    if telemetry is not None:
        telemetry.close()
    if verbose:
        print(render_tables(result))
        if step_watchdog is not None and step_watchdog.events:
            print(f"[talp watchdog] {len(step_watchdog.events)} anomaly "
                  f"event(s); first: {step_watchdog.events[0].as_dict()}")
    if talp_json:
        with open(talp_json, "w") as f:
            f.write(to_json(result))
    if talp_spool and step_recorder is not None:
        steps_transport = sample_transport or FileSpoolTransport(
            talp_spool, world_size=world_size, payload=talp_spool_format)
        steps_transport.submit_steps(step_recorder.series, rank=rank)
    if talp_spool:
        emit_job_report(result, talp_spool, rank, world_size, verbose=verbose,
                        payload=talp_spool_format, timelines=mon.devices,
                        fault_plan=fault_plan)
    if step_watchdog is not None:
        step_watchdog.close()
    return np.stack(tokens_out, axis=1), result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    ap.add_argument("--talp-json", default=None)
    ap.add_argument("--talp-sample-every", type=int, default=0,
                    help="every N decoded tokens publish a mid-run snapshot "
                         "and (with --talp-spool) merge a job-level report")
    ap.add_argument("--talp-spool", default=None,
                    help="shared dir for per-rank reports + job-level merge")
    ap.add_argument("--talp-spool-format", choices=("binary", "json"),
                    default="binary",
                    help="spool payload: versioned binary .npz (default) "
                         "or legacy JSON")
    ap.add_argument("--talp-trace-out", default=None,
                    help="write a Chrome/Perfetto trace JSON at exit")
    ap.add_argument("--talp-metrics-jsonl", default=None,
                    help="stream every TALP snapshot as one JSON line")
    ap.add_argument("--talp-prometheus-port", type=int, default=None,
                    help="serve the latest snapshot as Prometheus text "
                         "(0 = ephemeral port)")
    ap.add_argument("--talp-step-series", type=int, default=0,
                    help="keep the last N per-decode-step metric rows "
                         "(columnar ring; spooled with --talp-spool)")
    ap.add_argument("--talp-watchdog", action="store_true",
                    help="run the online efficiency anomaly watchdog over "
                         "per-decode-step rows (implies a step series)")
    ap.add_argument("--talp-anomaly-log", default=None,
                    help="stream watchdog anomaly events as JSONL "
                         "(implies --talp-watchdog)")
    ap.add_argument("--talp-fault-plan", default=None, metavar="SPEC",
                    help="deterministic collection-fault injection for "
                         "this rank (debug): inline JSON or a JSON file "
                         "with drop/truncate/corrupt/delay/clock_skew "
                         "sections keyed by rank id")
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--world-size", type=int, default=1)
    args = ap.parse_args()
    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    t0 = time.time()
    tokens, _ = serve(cfg, args.requests, args.prompt_len, args.gen_len,
                      talp_json=args.talp_json, rank=args.rank,
                      world_size=args.world_size, talp_spool=args.talp_spool,
                      talp_sample_every=args.talp_sample_every,
                      talp_spool_format=args.talp_spool_format,
                      talp_trace_out=args.talp_trace_out,
                      talp_metrics_jsonl=args.talp_metrics_jsonl,
                      talp_prometheus_port=args.talp_prometheus_port,
                      talp_step_series=args.talp_step_series,
                      talp_watchdog=args.talp_watchdog,
                      talp_anomaly_log=args.talp_anomaly_log,
                      talp_fault_plan=args.talp_fault_plan)
    dt = time.time() - t0
    n = tokens.size
    print(f"generated {n} tokens in {dt:.2f}s ({n/dt:.1f} tok/s)")
    sys.stdout.flush()


if __name__ == "__main__":
    main()
