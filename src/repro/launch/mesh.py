"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the default single device.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import numpy as np

__all__ = ["make_production_mesh", "make_mesh", "describe_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 (one pod, 256 chips) or 2×16×16 (two pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "set XLA_FLAGS=--xla_force_host_platform_device_count=512 "
            "before importing jax (dry-run only)"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None):
    """General mesh helper (tests / elastic rescaling)."""
    n = int(np.prod(shape))
    devices = list(devices if devices is not None else jax.devices())
    if len(devices) < n:
        raise RuntimeError(f"need {n} devices, have {len(devices)}")
    dev = np.asarray(devices[:n]).reshape(tuple(shape))
    return jax.sharding.Mesh(dev, tuple(axes))


def describe_mesh(mesh) -> str:
    return "x".join(
        f"{mesh.shape[a]}{a}" for a in mesh.axis_names
    )
