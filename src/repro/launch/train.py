"""End-to-end training driver with TALP monitoring as a first-class
feature.

Every step runs under TALP regions/states:
  * host *Useful*  — data synthesis + python control,
  * *Offload*      — device dispatch + blocked-on-device time (with a
                     device Kernel record via the runtime backend),
  * *MPI*          — cross-process control-plane waits (checkpoint
                     barrier in multi-process runs; ~0 single-process),
and the paper's text/JSON report is emitted at exit and every
``--talp-interval`` steps (TALP's online mode). Checkpoint/restart and
straggler detection are integrated (fault tolerance), and the data
pipeline prefetches in the background.

Usage (CPU-sized):
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from contextlib import nullcontext

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs import ShapeConfig, get_config, list_configs, smoke_config
from ..core.backends import RuntimeBackend
from ..core.merge import FileSpoolTransport, emit_job_report
from ..core.report import render_tables, to_json
from ..core.talp import TalpMonitor
from ..data.pipeline import DataConfig, SyntheticTokenPipeline
from ..optim.adamw import AdamWConfig
from ..runtime.fault_tolerance import StragglerDetector
from .steps import (
    init_train_state, make_train_step, model_flops, train_state_shapes,
)

__all__ = ["train", "main"]


def train(
    cfg,
    steps: int = 50,
    global_batch: int = 8,
    seq_len: int = 128,
    ckpt_dir: str = None,
    ckpt_every: int = 20,
    talp_interval: int = 0,
    talp_json: str = None,
    opt_cfg: AdamWConfig = None,
    fail_at_step: int = None,   # failure injection (tests)
    seed: int = 0,
    verbose: bool = True,
    rank: int = 0,
    world_size: int = 1,
    talp_spool: str = None,
    talp_sample_every: int = 0,
    talp_spool_format: str = "binary",
    talp_trace_out: str = None,
    talp_metrics_jsonl: str = None,
    talp_prometheus_port: int = None,
    talp_step_series: int = 0,
    talp_watchdog: bool = False,
    talp_anomaly_log: str = None,
    talp_fault_plan=None,
):
    """Train a (usually reduced) config; returns (state, history, talp).

    Multi-rank jobs: give each process its ``rank``/``world_size`` and a
    shared ``talp_spool`` directory — every rank spools its per-process
    TALP report there, and whichever rank completes the spool last merges
    it into the job-level report (``talp_job.json``).

    ``talp_sample_every=N`` additionally takes a non-destructive
    all-regions snapshot every N steps (``TalpMonitor.sample_result``);
    with a ``talp_spool`` the snapshot is published to the spool and
    merged across whichever ranks have reported so far — a *job-level*
    mid-run TALP report, TALP's online mode at job scope.

    Observability: ``talp_trace_out`` writes a Chrome/Perfetto trace of
    this rank at exit; ``talp_metrics_jsonl`` streams every snapshot as
    one JSON line; ``talp_prometheus_port`` serves the latest snapshot
    as Prometheus text on ``/metrics`` (0 = ephemeral port). The report
    carries the measured ``talp_overhead`` annotation.

    Per-step attribution: ``talp_step_series=N`` keeps the last N
    per-step metric rows (a ``step`` region wraps each iteration and its
    close is captured into a columnar ring; with a ``talp_spool`` the
    ring is spooled and rank-aligned into a job-level per-step table).
    ``talp_watchdog`` runs the online anomaly watchdog over those rows;
    ``talp_anomaly_log`` streams its events as JSONL (either implies the
    step series). The step model's FLOP estimate feeds the measured
    Computational Efficiency annotation.

    Debugging the fault-tolerant collection path: ``talp_fault_plan`` (a
    :class:`~repro.core.collect.FaultPlan` spec — inline JSON or a file
    path) deterministically injects collection failures for this rank:
    drop/delay/corrupt the spool submit, or skew the monitor clock.
    """
    from ..core.collect import FaultPlan

    fault_plan = (FaultPlan.from_spec(talp_fault_plan)
                  if talp_fault_plan is not None else None)
    clock = time.perf_counter
    if fault_plan is not None:
        skew = fault_plan.skew_s(rank)
        if skew:
            clock = lambda: time.perf_counter() + skew  # noqa: E731
        if verbose and fault_plan.touches(rank):
            print(f"[talp fault] rank {rank} plan: "
                  f"{fault_plan.describe(rank)}")
    opt_cfg = opt_cfg or AdamWConfig(warmup_steps=10, total_steps=steps)
    backend = RuntimeBackend()
    want_steps = bool(talp_step_series or talp_watchdog or talp_anomaly_log)
    flop_model = None
    if want_steps:
        from ..core.backends.analytical import StepModel

        shape = ShapeConfig(name="train", seq_len=seq_len,
                            global_batch=global_batch, kind="train")
        flop_model = StepModel(
            flops=0.0, hbm_bytes=0.0, collective_bytes=0.0,
            model_flops=model_flops(cfg, shape) / max(world_size, 1),
        )
    mon = TalpMonitor("train", rank=rank, clock=clock, backend=backend,
                      overhead_report=True, flop_model=flop_model)
    step_recorder = step_watchdog = None
    if want_steps:
        from ..core.telemetry.stepseries import StepSeriesRecorder

        if talp_watchdog or talp_anomaly_log:
            from ..core.telemetry.watchdog import EfficiencyWatchdog

            step_watchdog = EfficiencyWatchdog(jsonl=talp_anomaly_log)
        step_recorder = StepSeriesRecorder(
            mon, capacity=talp_step_series or 4096,
            regions=("step",), watchdog=step_watchdog,
        )
    sample_transport = (
        FileSpoolTransport(talp_spool, world_size=world_size,
                           payload=talp_spool_format)
        if talp_spool and talp_sample_every else None
    )
    telemetry = None
    if talp_metrics_jsonl or talp_prometheus_port is not None or talp_trace_out:
        from ..core.telemetry.exporter import TelemetryExporter

        telemetry = TelemetryExporter(mon, jsonl=talp_metrics_jsonl,
                                      watchdog=step_watchdog)
        if talp_prometheus_port is not None:
            port = telemetry.serve(port=talp_prometheus_port)
            if verbose:
                print(f"[talp] prometheus exposition on :{port}/metrics")

    data = SyntheticTokenPipeline(
        DataConfig(
            global_batch=global_batch,
            seq_len=seq_len,
            vocab_size=cfg.vocab_size,
            embed_dim=cfg.d_model if cfg.frontend == "embed" else 0,
            seed=seed,
        ),
        process_index=rank,
        process_count=world_size,
    )

    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=0)
    manager = CheckpointManager(ckpt_dir) if ckpt_dir else None
    detector = StragglerDetector()

    # --- init or resume ---------------------------------------------------
    start_step = 0
    state = None
    if manager is not None:
        state, start_step = manager.restore_latest(train_state_shapes(cfg))
    if state is None:
        with mon.region("init"):
            state = init_train_state(cfg, jax.random.PRNGKey(seed))
            state = jax.block_until_ready(state)
        start_step = 0

    history = []
    with mon.region("train_loop"):
        for step in range(start_step, steps):
            t0 = time.perf_counter()
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            # A nested per-step region only when the step series is on:
            # its close is what the recorder/watchdog capture.
            with (mon.region("step") if step_recorder is not None
                  else nullcontext()):
                # host Useful: data synthesis (prefetch keeps this short)
                batch = data.batch_at(step)
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                # Offload: dispatch + block (async launch → kernel record)
                handle = backend.launch(step_fn, state, batch,
                                        name="train_step")
                with mon.offload():
                    state, metrics = backend.wait(handle)
                if manager is not None and (step + 1) % ckpt_every == 0:
                    # snapshot is sync (short), file write is async
                    with mon.mpi():   # control-plane barrier analogue
                        manager.save(step, state)
            dt = time.perf_counter() - t0
            detector.observe(step, dt)
            history.append(
                {"step": step, "loss": float(metrics["loss"]),
                 "grad_norm": float(metrics["grad_norm"]), "time_s": dt}
            )
            if talp_interval and (step + 1) % talp_interval == 0 and verbose:
                snap = mon.sample("train_loop")
                print(f"[talp online] step {step} "
                      f"PE_host={snap.host.parallel_efficiency:.3f} "
                      f"OE={snap.host.device_offload_efficiency:.3f}")
            if talp_sample_every and (step + 1) % talp_sample_every == 0:
                # Through the telemetry exporter when one is attached,
                # so the snapshot also lands in the ring buffer and the
                # JSONL/Prometheus stream.
                snapshot = (
                    telemetry.sample().result if telemetry is not None
                    else mon.sample_result()
                )
                if sample_transport is not None:
                    sample_transport.submit_sample(snapshot, rank=rank)
                    job_snap = sample_transport.merge_samples(name=mon.name)
                else:
                    job_snap = snapshot
                if verbose:
                    g = job_snap.regions.get(TalpMonitor.GLOBAL)
                    if g is not None and g.host is not None:
                        print(f"[talp sample] step {step} "
                              f"ranks={g.n_ranks} devices={g.n_devices} "
                              f"PE_host={g.host.parallel_efficiency:.3f}")
            if verbose and (step % 10 == 0 or step == steps - 1):
                print(f"step {step:5d} loss {history[-1]['loss']:.4f} "
                      f"({dt*1e3:.0f} ms)")
                sys.stdout.flush()

    if manager is not None:
        manager.save(steps - 1, state)
        manager.wait()
    data.stop()
    if telemetry is not None:
        # Final snapshot while the monitor still runs: the stream's last
        # record and the post-mortem report describe the same window.
        telemetry.sample()
    if step_recorder is not None:
        step_recorder.close()   # detach before finalize's Global close
    result = mon.finalize()
    if talp_trace_out:
        from ..core.telemetry.traceexport import export_monitor

        with open(talp_trace_out, "w") as f:
            f.write(export_monitor(
                mon, result=result,
                samples=telemetry.trace_samples() if telemetry else None,
                step_series=(step_recorder.series
                             if step_recorder is not None else None),
                anomalies=(step_watchdog.events
                           if step_watchdog is not None else None),
            ))
        if verbose:
            print(f"[talp] wrote Chrome trace: {talp_trace_out}")
    if telemetry is not None:
        telemetry.close()
    if verbose:
        print(render_tables(result))
        if detector.events:
            print(f"straggler events at steps: {detector.events}")
        if step_watchdog is not None and step_watchdog.events:
            print(f"[talp watchdog] {len(step_watchdog.events)} anomaly "
                  f"event(s); first: {step_watchdog.events[0].as_dict()}")
    if talp_json:
        with open(talp_json, "w") as f:
            f.write(to_json(result))
    if talp_spool and step_recorder is not None:
        steps_transport = sample_transport or FileSpoolTransport(
            talp_spool, world_size=world_size, payload=talp_spool_format)
        steps_transport.submit_steps(step_recorder.series, rank=rank)
    if talp_spool:
        emit_job_report(result, talp_spool, rank, world_size, verbose=verbose,
                        payload=talp_spool_format, timelines=mon.devices,
                        fault_plan=fault_plan)
    if step_watchdog is not None:
        step_watchdog.close()
    return state, history, result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--talp-interval", type=int, default=0)
    ap.add_argument("--talp-sample-every", type=int, default=0,
                    help="every N steps publish a mid-run snapshot and "
                         "(with --talp-spool) merge a job-level report")
    ap.add_argument("--talp-json", default=None)
    ap.add_argument("--talp-spool", default=None,
                    help="shared dir for per-rank reports + job-level merge")
    ap.add_argument("--talp-spool-format", choices=("binary", "json"),
                    default="binary",
                    help="spool payload: versioned binary .npz (default) "
                         "or legacy JSON")
    ap.add_argument("--talp-trace-out", default=None,
                    help="write a Chrome/Perfetto trace JSON of this rank "
                         "at exit")
    ap.add_argument("--talp-metrics-jsonl", default=None,
                    help="stream every TALP snapshot as one JSON line to "
                         "this file")
    ap.add_argument("--talp-prometheus-port", type=int, default=None,
                    help="serve the latest snapshot as Prometheus text on "
                         "this port (0 = ephemeral)")
    ap.add_argument("--talp-step-series", type=int, default=0,
                    help="keep the last N per-step metric rows (columnar "
                         "ring; spooled + rank-aligned with --talp-spool)")
    ap.add_argument("--talp-watchdog", action="store_true",
                    help="run the online efficiency anomaly watchdog over "
                         "the per-step rows (implies a step series)")
    ap.add_argument("--talp-anomaly-log", default=None,
                    help="stream watchdog anomaly events as JSONL to this "
                         "file (implies --talp-watchdog)")
    ap.add_argument("--talp-fault-plan", default=None, metavar="SPEC",
                    help="deterministic collection-fault injection for "
                         "this rank (debug): inline JSON or a JSON file "
                         "with drop/truncate/corrupt/delay/clock_skew "
                         "sections keyed by rank id")
    ap.add_argument("--rank", type=int, default=0)
    ap.add_argument("--world-size", type=int, default=1)
    ap.add_argument("--history-json", default=None)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    _, history, _ = train(
        cfg,
        steps=args.steps,
        global_batch=args.batch,
        seq_len=args.seq,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=args.ckpt_every,
        talp_interval=args.talp_interval,
        talp_json=args.talp_json,
        rank=args.rank,
        world_size=args.world_size,
        talp_spool=args.talp_spool,
        talp_sample_every=args.talp_sample_every,
        talp_spool_format=args.talp_spool_format,
        talp_trace_out=args.talp_trace_out,
        talp_metrics_jsonl=args.talp_metrics_jsonl,
        talp_prometheus_port=args.talp_prometheus_port,
        talp_step_series=args.talp_step_series,
        talp_watchdog=args.talp_watchdog,
        talp_anomaly_log=args.talp_anomaly_log,
        talp_fault_plan=args.talp_fault_plan,
    )
    if args.history_json:
        with open(args.history_json, "w") as f:
            json.dump(history, f)
    losses = [h["loss"] for h in history]
    if losses and not (np.isfinite(losses[-1]) and losses[-1] < losses[0]):
        print("WARNING: loss did not decrease")


if __name__ == "__main__":
    main()
