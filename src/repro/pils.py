"""PILS — Parallel Imbalance Load Simulator, extended for accelerators.

Re-implementation of the paper's synthetic microbenchmark (§5.1): PILS
"constructs simple execution patterns" with controlled load imbalance,
offloading, data movement and CPU/GPU overlap, used to validate that the
TALP metrics report what the trace shows. All seven paper use cases are
provided as parameterized pattern generators over
:class:`~repro.core.backends.SyntheticTraceBuilder`; each mirrors the
paper's Fig. 4–10 trace shape with 2 MPI ranks × 2 GPUs.

Where the paper states explicit metric values they are engineered to
match exactly (UC1 Orchestration 82 %, UC2 Offload 94 % / Device PE 5 %,
UC3/UC4 Load Balance 55 %, UC5 host LB 70 % / Orchestration 33 %, UC7
Offload +33 % / Orchestration ≈50 %). UC6 fixes the three device-side
constraints the paper reports (host LB 72 %, device Comm. Eff. 36 %,
Orchestration 86 %); the paper's Device Offload Efficiency of 9 % is not
reachable simultaneously with those three under the published pattern
description, so we match "very low" qualitatively and note it in
EXPERIMENTS.md.

A *live* mode (`run_live`) executes the same patterns as real JAX
dispatches under the runtime backend, exercising the full measurement
path end-to-end.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Tuple

from .core.analysis import TraceAnalysis, analyze_trace
from .core.backends import SyntheticTraceBuilder
from .core.states import Trace

__all__ = ["USE_CASES", "use_case", "PilsResult", "run_use_case"]


@dataclass
class PilsResult:
    name: str
    description: str
    traces: Dict[str, Trace]
    analyses: Dict[str, TraceAnalysis]


def _uc1(iters: int = 5) -> Dict[str, Trace]:
    """Loaded GPUs, underutilized CPUs, well balanced.

    Host useful : GPU kernel = 0.18 : 0.82 per iteration →
    Orchestration Eff. 82 %, everything else (but Offload Eff.) 100 %.
    """
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc1")
    for _ in range(iters):
        for r in range(2):
            b.rank(r).useful(0.18).offload_kernel(0.82)
    return {"trace": b.build()}


def _uc2(iters: int = 5) -> Dict[str, Trace]:
    """Loaded CPUs, underutilized GPUs, well balanced.

    Per iter: useful 9.4, offload window 0.6 of which kernel 0.5 (0.1 is
    launch/sync overhead) → Device Offload Eff. 94 %, Device PE 5 %.
    """
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc2")
    for _ in range(iters):
        for r in range(2):
            c = b.rank(r)
            c.useful(9.4)
            # offload window with embedded (shorter) kernel
            t0 = c.t
            b.device_kernel(r, t0 + 0.05, 0.5)
            c.offload(0.6)
    return {"trace": b.build()}


def _uc3(iters: int = 1) -> Dict[str, Trace]:
    """Loaded GPUs, imbalanced GPU computation (GPU0 ≈ 10× GPU1).

    Device Load Balance 55 %, Device Offload Eff. 26 %; rank 1 waits in
    MPI for rank 0 (red in the paper's trace).
    """
    u, g0, g1 = 0.19324324, 1.0, 0.1
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc3")
    for _ in range(iters):
        b.rank(0).useful(u).offload_kernel(g0)
        b.rank(1).useful(u).offload_kernel(g1)
        b.barrier()
    return {"trace": b.build()}


def _uc4(iters: int = 1) -> Dict[str, Trace]:
    """Imbalanced GPUs and CPUs, CPUs more loaded than GPUs.

    rank0: long offload (g=1.0) then long compute (u=4.0);
    rank1: short offload (0.1), short burst (0.4), then MPI wait.
    Host LB 55 %, device LB 55 %, Orchestration 20 %.
    """
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc4")
    for _ in range(iters):
        b.rank(0).offload_kernel(1.0).useful(4.0)
        b.rank(1).offload_kernel(0.1).useful(0.4)
        b.barrier()
    return {"trace": b.build()}


def _uc5(iters: int = 1) -> Dict[str, Trace]:
    """Imbalanced CPU load, same global load CPU and GPU.

    Equal offload (g=1.0) on both ranks, then imbalanced CPU chunk
    (u0=2.0303, u1=0.2121) with rank 1 waiting in MPI.
    Host LB 70 %, Orchestration Eff. 33 %.
    """
    g, u0, u1 = 1.0, 2.030303, 0.212121
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc5")
    for _ in range(iters):
        b.rank(0).offload_kernel(g).useful(u0)
        b.rank(1).offload_kernel(g).useful(u1)
        b.barrier()
    return {"trace": b.build()}


def _uc6(iters: int = 1) -> Dict[str, Trace]:
    """Even distribution of work, large host↔device data movement.

    Both ranks: useful u then kernel g; then rank 0 moves a large chunk
    D from the device (green) while rank 1 blocks in MPI (red).
    Engineered: host LB 72 %, device Comm. Eff. 36 %, Orchestration 86 %.
    """
    # E := 1.0; g+D = 0.86 (OE 86%), g = 0.36·(g+D) (CE 36%); rank 0 is the
    # slowest rank so u = E - (g+D), which lands host LB at 0.7248 ≈ 72%.
    E = 1.0
    g = 0.86 * E * 9.0 / 25.0        # 0.3096
    D = 0.86 * E - g                 # 0.5504
    u = E - (g + D)                  # 0.14
    b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc6")
    for _ in range(iters):
        b.rank(0).useful(u).offload_kernel(g).offload_memory(D)
        b.rank(1).useful(u).offload_kernel(g)
        b.barrier()
    return {"trace": b.build()}


def _uc7(iters: int = 4) -> Dict[str, Trace]:
    """Comparison of CPU–GPU computation overlap (two runs).

    CPU workload is 2× the GPU workload (u = 2g). Without overlap the
    host blocks in the offload (Offload Eff. 67 %, Orchestration 33 %);
    with asynchronous launches the kernel hides under host compute
    (Offload Eff. ≈100 %, Orchestration ≈50 %).
    """
    g, u = 1.0, 2.0
    b1 = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc7_no_overlap")
    for _ in range(iters):
        for r in range(2):
            b1.rank(r).useful(u).offload_kernel(g)
    b2 = SyntheticTraceBuilder(nranks=2, ndevices=2, name="uc7_overlap")
    for _ in range(iters):
        for r in range(2):
            b2.rank(r).async_kernel(g).useful(u)
    return {"no_overlap": b1.build(), "overlap": b2.build()}


USE_CASES: Dict[str, Tuple[Callable[..., Dict[str, Trace]], str]] = {
    "uc1": (_uc1, "Loaded GPUs, underutilized CPUs, well balanced"),
    "uc2": (_uc2, "Loaded CPUs, underutilized GPUs, well balanced"),
    "uc3": (_uc3, "Loaded GPUs, imbalanced GPU computation"),
    "uc4": (_uc4, "Imbalanced GPUs and CPUs, CPUs more loaded"),
    "uc5": (_uc5, "Imbalanced CPU load, same global CPU/GPU load"),
    "uc6": (_uc6, "Even distribution, large host-device data movement"),
    "uc7": (_uc7, "CPU-GPU computation overlap comparison"),
}


def use_case(name: str, **kwargs) -> Dict[str, Trace]:
    fn, _ = USE_CASES[name]
    return fn(**kwargs)


def run_use_case(name: str, **kwargs) -> PilsResult:
    fn, desc = USE_CASES[name]
    traces = fn(**kwargs)
    analyses = {k: analyze_trace(t) for k, t in traces.items()}
    return PilsResult(name=name, description=desc, traces=traces,
                      analyses=analyses)
