"""TALP-JAX: the paper's efficiency-metric framework (repro.core) inside
a multi-pod JAX training/serving stack. See README.md / DESIGN.md."""
