"""Synthetic sharded data pipeline with background prefetch.

Production posture: each host process generates/loads only its shard of
the global batch (``process_index``-keyed), a background thread keeps a
bounded prefetch queue full (host data work overlaps device compute —
exactly the overlap TALP's Offload/Orchestration metrics reward, see
use case 7), and batches are deterministic functions of (seed, step) so
a restart reproduces the same stream — the property checkpoint/resume
tests rely on.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticTokenPipeline"]


@dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    embed_dim: int = 0        # >0 → embedding frontend (VLM/audio stub)
    prefetch: int = 2


class SyntheticTokenPipeline:
    """Deterministic synthetic LM stream, sharded across processes."""

    def __init__(self, cfg: DataConfig,
                 process_index: Optional[int] = None,
                 process_count: Optional[int] = None):
        self.cfg = cfg
        self.pidx = (jax.process_index() if process_index is None
                     else process_index)
        self.pcount = (jax.process_count() if process_count is None
                       else process_count)
        if cfg.global_batch % self.pcount:
            raise ValueError("global batch must divide process count")
        self.local_batch = cfg.global_batch // self.pcount
        self._q: "queue.Queue" = queue.Queue(maxsize=cfg.prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread: Optional[threading.Thread] = None

    # -- deterministic batch synthesis -----------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + step) * 65_537 + self.pidx
        )
        c = self.cfg
        labels = rng.integers(
            0, c.vocab_size, (self.local_batch, c.seq_len), dtype=np.int32
        )
        if c.embed_dim:
            inputs = rng.standard_normal(
                (self.local_batch, c.seq_len, c.embed_dim), dtype=np.float32
            )
        else:
            inputs = np.roll(labels, 1, axis=1)   # next-token structure
            inputs[:, 0] = 0
        return {"inputs": inputs, "labels": labels}

    # -- prefetch loop -----------------------------------------------------
    def _worker(self, start_step: int):
        step = start_step
        while not self._stop.is_set():
            batch = self.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def start(self, start_step: int = 0) -> None:
        self._stop.clear()
        self._step = start_step
        self._thread = threading.Thread(
            target=self._worker, args=(start_step,), daemon=True
        )
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread is not None:
            # drain so the worker can observe the stop flag
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        if self._thread is None:
            self.start(self._step)
        while True:
            step, batch = self._q.get()
            self._step = step + 1
            yield batch
