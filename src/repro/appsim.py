"""Workload emulators for the paper's three production applications
(§5.2): mechanistic models of each code's documented behavior, emitting
standard ``Trace`` objects the TALP pipeline analyzes — reproducing the
structure of Tables 1–3 across a 1→8 node scan (4 GPUs + 4 ranks per
node, as on MareNostrum5-ACC).

The models are *forward* simulations (work decomposition + scaling laws),
not curve fits per cell: constants are set so the 1-node column matches
the paper closely, and the node-scan trends (which metric degrades and
why) emerge from the model:

  * SOD2D  — GPU-resident SEM solver: all compute offloaded (DOE ~0.06),
    kernels strong-scale 1/n, host MPI share grows with n → host Comm.
    Eff. and device Orchestration Eff. degrade together.
  * FALL3D — init-dominated ADS model: rank 0 distributes the workload
    while others wait (host LB ∝ 1/n), GPU work is a small fraction →
    Orchestration Eff. collapses with n while Offload Eff. *rises*.
  * XSHELLS — balanced spectral code: a non-scaling MPI-heavy init phase
    (I ∝ n^0.75) erodes host Comm. Eff. and device Orchestration as the
    iterative phase shrinks.
"""

from __future__ import annotations

from typing import Dict, List

from .core.analysis import TraceAnalysis, analyze_trace
from .core.backends import SyntheticTraceBuilder
from .core.states import Trace

__all__ = ["sod2d_trace", "fall3d_trace", "xshells_trace", "node_scan"]

RANKS_PER_NODE = 4  # MN5-ACC: 4 H100 + 4 ranks per node


def sod2d_trace(nodes: int, steps: int = 3) -> Trace:
    """GPU-dominant spectral-element CFD (Table 1)."""
    r = RANKS_PER_NODE * nodes
    g = 4.0 / r                    # per-device kernel time (strong scaling)
    mem = 0.01 * g                 # small D2H/H2D traffic (device CE ~0.99)
    w = g + mem                    # host blocked during offload
    u = w * 6.0 / 94.0             # DOE ≈ 0.06: host only orchestrates
    # host MPI share grows with scale: (1-CE)/CE = 0.0526 · n^1.05
    mpi = (u + w) * 0.0526 * nodes ** 1.05
    b = SyntheticTraceBuilder(nranks=r, ndevices=r, name=f"sod2d_n{nodes}")
    for _ in range(steps):
        for i in range(r):
            c = b.rank(i)
            c.useful(u)
            c.offload_kernel(g * (1.0 - 0.005 * (i % 4)))   # ~1% device LB
            c.offload_memory(mem)
            c.mpi(mpi)
        b.barrier()
    return b.build()


def fall3d_trace(nodes: int, steps: int = 3) -> Trace:
    """Init-dominated atmospheric transport (Table 2)."""
    r = RANKS_PER_NODE * nodes
    g1 = 1.0                       # kernel unit at 1 node
    g = 4.0 * g1 / r               # per-device kernel, strong scaling
    tr = 0.28 * g                  # transfers → device CE ≈ 0.78
    u = 0.783 * 4.0 * g1 / r       # per-rank host compute, strong scaling
    d_init = 3.67 * g1 * steps     # rank-0 workload distribution (serial,
    #                                scales with problem size = steps here)
    mpi_it = 1.01 * g1 * 0.33      # iterative MPI per step (weakly scaling)
    b = SyntheticTraceBuilder(nranks=r, ndevices=r, name=f"fall3d_n{nodes}")
    # --- init: rank 0 distributes, everyone else waits in MPI ---
    b.rank(0).useful(d_init)
    b.barrier()
    # --- iterative phase ---
    for _ in range(steps):
        for i in range(r):
            c = b.rank(i)
            c.useful(u * (1.0 + 0.01 * (i % 4)))
            c.offload_kernel(g * (1.0 - 0.01 * (i % 4)))    # device LB ~0.98
            c.offload_memory(tr)
            c.mpi(mpi_it)
        b.barrier()
    return b.build()


def xshells_trace(nodes: int, steps: int = 3) -> Trace:
    """Balanced rotating-Navier-Stokes spectral code (Table 3)."""
    r = RANKS_PER_NODE * nodes
    g = 4.0 / r                    # kernel, strong scaling
    mem = 0.02 * g                 # device CE ~0.98
    w = g + mem
    # CPU work scales sublinearly (n^-0.7) → Offload Eff. rises with n,
    # matching the paper's "work done by CPUs increases as we scale"
    u = (2.0 / 3.0) * (w * r / 4.0) * (1.0 / nodes) ** 0.7
    # non-scaling MPI-heavy init: absolute time grows ~n^0.6
    i_mpi = 0.17 * nodes ** 0.6
    b = SyntheticTraceBuilder(nranks=r, ndevices=r, name=f"xshells_n{nodes}")
    for _ in range(steps):
        for i in range(r):
            c = b.rank(i)
            c.mpi(i_mpi / steps)                     # non-scaling init share
            c.useful(u * (1.0 + 0.005 * (i % 4)))    # host LB ~0.98
            c.offload_kernel(g)
            c.offload_memory(mem)
        b.barrier()
    return b.build()


def node_scan(app: str, nodes: List[int] = (1, 2, 4, 8),
              steps: int = 3) -> Dict[int, TraceAnalysis]:
    fn = {"sod2d": sod2d_trace, "fall3d": fall3d_trace,
          "xshells": xshells_trace}[app]
    return {n: analyze_trace(fn(n, steps=steps)) for n in nodes}
