"""Activation-sharding hints, threaded to the model via a trace-time
context (the model code stays mesh-agnostic).

``activation_sharding(P(fsdp, "model", None))`` makes every layer
boundary constrain the residual stream to that spec — batch over the
FSDP axes and *sequence over the model axis* (sequence parallelism).
With full remat the saved per-layer residual is exactly this buffer, so
the constraint divides the dominant activation-memory term by the model
axis size; XLA inserts all-gather/reduce-scatter pairs around the
attention/FFN compute (the standard SP trade of collective bytes for
HBM footprint).
"""

from __future__ import annotations

from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import PartitionSpec as P

__all__ = ["activation_sharding", "constrain", "current_spec",
           "moe_weight_sharding", "current_moe_specs"]

_SPEC: Optional[P] = None
_MOE_SPECS = None   # (gate/up spec, down spec) for gathered MoE weights


@contextmanager
def activation_sharding(spec: Optional[P]):
    global _SPEC
    prev = _SPEC
    _SPEC = spec
    try:
        yield
    finally:
        _SPEC = prev


def current_spec() -> Optional[P]:
    return _SPEC


@contextmanager
def moe_weight_sharding(gate_up: Optional[P], down: Optional[P]):
    """Compute-time layout for gathered MoE expert weights (§Perf A4/A5):
    the FSDP-sharded d_model dim must be gathered before the expert
    einsums, while expert/d_ff dims keep EP/TP — the launcher pins the
    exact spec because XLA's free placement (UNCONSTRAINED) picked
    partial-sum all-reduces of the fat (g,e,c,f) activations instead."""
    global _MOE_SPECS
    prev = _MOE_SPECS
    _MOE_SPECS = (gate_up, down)
    try:
        yield
    finally:
        _MOE_SPECS = prev


def current_moe_specs():
    return _MOE_SPECS


def constrain(x: jax.Array) -> jax.Array:
    """Apply the ambient activation spec to a (B, S, M) tensor."""
    if _SPEC is None or x.ndim != 3:
        return x
    return jax.lax.with_sharding_constraint(x, _SPEC)


def constrain_seq_gathered(x: jax.Array) -> jax.Array:
    """Batch-sharded but sequence-REPLICATED layout for a (B, S, ...)
    tensor: the explicit SP→attention gather point. Pinning this on the
    (small, bf16) K/V projections stops XLA from instead all-gathering
    the fp32 internals of the preceding norm (§Perf iter C4)."""
    if _SPEC is None:
        return x
    batch_ax = _SPEC[0] if len(_SPEC) > 0 else None
    spec = P(*((batch_ax,) + (None,) * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)
