"""Logical-axis partitioning rules → NamedSharding trees.

Strategy (1000+-chip posture, DESIGN.md §5):

  * mesh axes ``("pod", "data", "model")`` (multi-pod) or
    ``("data", "model")`` (single pod); ``pod``+``data`` form one
    FSDP/DP super-axis (batch sharding + ZeRO-3 parameter/optimizer
    sharding), ``model`` carries tensor/expert parallelism;
  * every rule checks divisibility against the actual mesh and falls
    back (shard a different dim, or replicate) — this is what lets one
    rule set serve all ten architectures (e.g. gemma-2's 4 KV heads
    can't split 16-ways → its decode caches shard over sequence
    instead);
  * stacked (scan) parameters carry a leading repeat dim that is never
    sharded.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "fsdp_axes",
    "param_pspec",
    "state_shardings",
    "batch_pspec",
    "cache_pspec",
    "make_sharding_tree",
]


def fsdp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return dim % _axis_size(mesh, axes) == 0


def _maybe(dim: int, mesh: Mesh, axes):
    """axes if divisible else None (replicate)."""
    return axes if _fits(dim, mesh, axes) else None


def param_pspec(path: Tuple[str, ...], leaf, mesh: Mesh, cfg) -> P:
    """Partition spec for one parameter, keyed by its tree path."""
    fsdp = fsdp_axes(mesh)
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = names[-1]
    stacked = "slots" in names  # leading scan-repeat dim
    shape = leaf.shape[1:] if stacked else leaf.shape

    def out(*spec):
        spec = tuple(
            _maybe(shape[i], mesh, ax) if ax is not None else None
            for i, ax in enumerate(spec)
        )
        return P(*((None,) + spec)) if stacked else P(*spec)

    if name == "embed":
        return out(fsdp, "model")
    if name == "unembed":
        return out(fsdp, "model")
    if name in ("wq", "wk", "wv", "wz", "wx", "wb", "wc", "wdt",
                "w_gate", "w_up", "router"):
        if len(shape) == 3:  # MoE expert-stacked (E, M, F)
            if _fits(shape[0], mesh, ("model",)):
                return out("model", fsdp, None)   # expert parallel
            return out(None, fsdp, "model")       # TP inside each expert
        return out(fsdp, "model")
    if name in ("wo", "w_down"):
        if len(shape) == 3:  # MoE (E, F, M)
            if _fits(shape[0], mesh, ("model",)):
                return out("model", None, fsdp)
            return out(None, "model", fsdp)
        return out("model", fsdp)
    if name.startswith("conv_"):
        return out(None, "model")
    if name == "norm":  # ssm gated-norm scale over d_inner
        return out("model")
    # 1-D scales / biases (ln*, final_norm, a_log, dt_bias, d_skip)
    return P(*((None,) * leaf.ndim))


def make_sharding_tree(tree, mesh: Mesh, cfg, spec_fn):
    """Map a pytree of arrays/ShapeDtypeStructs to NamedShardings."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, spec_fn(path, leaf, mesh, cfg)),
        tree,
    )


def state_shardings(state_shapes, mesh: Mesh, cfg):
    """Shardings for a TrainState {params, opt{mu, nu}, step}: optimizer
    moments inherit the parameter rule (ZeRO: they are sharded exactly
    like the FSDP parameters)."""

    def spec(path, leaf, mesh_, cfg_):
        names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
        if names and names[0] in ("params", "mu", "nu"):
            return param_pspec(tuple(path[1:]), leaf, mesh_, cfg_)
        if names[:2] == ["opt", "mu"] or names[:2] == ["opt", "nu"]:
            return param_pspec(tuple(path[2:]), leaf, mesh_, cfg_)
        return P()  # scalars (step counters, loss scales)

    return make_sharding_tree(state_shapes, mesh, cfg, spec)


def batch_pspec(mesh: Mesh, batch_size: int, ndim: int) -> P:
    """Batch-leading activations: shard batch over the FSDP axes when
    divisible (long_500k's batch=1 replicates)."""
    fsdp = fsdp_axes(mesh)
    lead = fsdp if batch_size % _axis_size(mesh, fsdp) == 0 else None
    return P(*((lead,) + (None,) * (ndim - 1)))


def cache_pspec(path: Tuple[str, ...], leaf, mesh: Mesh, cfg) -> P:
    """Decode-cache shardings (stacked leading repeat dim).

    kv caches (R, B, T, K, D): batch over FSDP when divisible; KV heads
    over ``model`` when divisible, else sequence over ``model`` (and for
    batch=1, sequence additionally takes the FSDP axes)."""
    fsdp = fsdp_axes(mesh)
    names = [getattr(p, "key", getattr(p, "name", str(p))) for p in path]
    name = names[-1]
    shape = leaf.shape
    if name in ("hk", "hv"):
        # hot decode ring: mutable every step → batch-local only; heads
        # over model when divisible, NEVER the (tiny) sequence dim
        _, b, _, k, _ = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        return P(None, b_ax, None, _maybe(k, mesh, ("model",)), None)
    if name == "h_pos":
        _, b, _ = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        return P(None, b_ax, None)
    if name in ("k", "v"):
        _, b, t, k, d = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        if _fits(k, mesh, ("model",)):
            t_ax = None if b_ax is not None else _maybe(t, mesh, fsdp)
            return P(None, b_ax, t_ax, "model", None)
        # sequence sharding fallback
        t_axes = ("model",) if b_ax is not None else tuple(fsdp) + ("model",)
        return P(None, b_ax, _maybe(t, mesh, t_axes), None, None)
    if name == "kv_pos":
        _, b, t = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        kv = cfg.num_kv_heads
        if _fits(kv, mesh, ("model",)):
            t_ax = None if b_ax is not None else _maybe(t, mesh, fsdp)
            return P(None, b_ax, t_ax)
        t_axes = ("model",) if b_ax is not None else tuple(fsdp) + ("model",)
        return P(None, b_ax, _maybe(t, mesh, t_axes))
    if name == "state":  # (R, B, H, P, N)
        _, b, h, _, _ = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        return P(None, b_ax, _maybe(h, mesh, ("model",)), None, None)
    if name.startswith("conv_"):  # (R, B, K-1, C)
        _, b, _, c = shape
        b_ax = fsdp if _fits(b, mesh, fsdp) else None
        return P(None, b_ax, None, _maybe(c, mesh, ("model",)))
    return P(*((None,) * leaf.ndim))
