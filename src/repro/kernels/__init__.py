from . import flash_attention, ssd

__all__ = ["flash_attention", "ssd"]
