"""Pure-jnp oracle for the flash-attention kernel: naive full-softmax
GQA attention with causal/sliding-window masking and logit soft-capping.
Materializes the full score matrix — correctness reference only."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

__all__ = ["attention_reference"]


def attention_reference(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)
    v: jax.Array,            # (B, T, K, D)
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    b, s, h, d = q.shape
    t, nk = k.shape[1], k.shape[2]
    g = h // nk
    qr = q.reshape(b, s, nk, g, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bskgd,btkd->bskgt", qr, kf) * (d ** -0.5)
    if softcap is not None:
        scores = softcap * jnp.tanh(scores / softcap)
    rows = jnp.arange(s)[:, None]
    cols = jnp.arange(t)[None, :]
    mask = jnp.ones((s, t), bool)
    if causal:
        # aligned ends: query i attends to keys ≤ i + (t - s)
        mask &= cols <= rows + (t - s)
        if window is not None:
            mask &= cols > rows + (t - s) - window
    scores = jnp.where(mask[None, :, None, None, :], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bskgt,btkd->bskgd", p, vf)
    return out.reshape(b, s, h, d).astype(q.dtype)
