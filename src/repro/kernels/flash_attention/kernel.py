"""Pallas TPU flash-attention kernel (blockwise online softmax, GQA,
sliding window, logit soft-capping).

TPU adaptation (not a CUDA port): the grid is
``(batch, kv_head, q_group, S/bq, T/bk)`` with the KV-block index
innermost — on TPU the grid is executed sequentially minor-to-major, so
the (m, l, acc) running statistics live in VMEM scratch and persist
across the KV sweep for a fixed query tile (the canonical TPU
"revisiting output block" pattern; no atomics / shared-memory tricks as
on GPU). Block shapes are multiples of the (8, 128) VREG tile and sized
so the working set (q tile + kv tile + acc) fits VMEM.

Masking: causal + optional sliding window, applied per (q, kv) tile;
fully-masked tiles short-circuit via ``pl.when`` (the kv sweep still
visits them, but skips the matmuls).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # (bq, D), (bk, D), (bk, D)
    o_ref,                        # (bq, D)
    m_ref, l_ref, acc_ref,        # scratch: (bq, 128), (bq, 128), (bq, D)
    *,
    bq: int,
    bk: int,
    seq_q: int,
    seq_kv: int,
    causal: bool,
    window: Optional[int],
    softcap: Optional[float],
    scale: float,
):
    i = pl.program_id(3)          # q block
    j = pl.program_id(4)          # kv block
    nj = pl.num_programs(4)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    # aligned ends: query r attends keys ≤ r + (T - S)
    shift = seq_kv - seq_q
    valid = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        valid &= cols <= rows + shift
        if window is not None:
            valid &= cols > rows + shift - window

    # skip tiles with no valid position (beyond causal frontier / window)
    if causal:
        block_live = j * bk <= (i * bq + bq - 1) + shift
        if window is not None:
            block_live &= (j * bk + bk - 1) > (i * bq) + shift - window
    else:
        block_live = jnp.bool_(True)

    @pl.when(block_live)
    def _compute():
        q = q_ref[...].astype(jnp.float32) * scale
        k = k_ref[...].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                              # (bq, bk)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        acc_ref[...] = (
            acc_ref[...] * alpha[:, None]
            + jax.lax.dot_general(
                p, v_ref[...].astype(jnp.float32),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(j == nj - 1)
    def _finalize():
        l = l_ref[:, 0]
        o_ref[...] = (
            acc_ref[...] / jnp.maximum(l, 1e-30)[:, None]
        ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "block_q", "block_kv", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,            # (B, S, H, D)
    k: jax.Array,            # (B, T, K, D)
    v: jax.Array,            # (B, T, K, D)
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    t, nk = k.shape[1], k.shape[2]
    if h % nk != 0:
        raise ValueError(f"GQA requires H % K == 0, got {h} % {nk}")
    g = h // nk
    bq = min(block_q, s)
    bk = min(block_kv, t)
    if s % bq or t % bk:
        raise ValueError(f"S/T must divide block sizes: {s}%{bq}, {t}%{bk}")

    grid = (b, nk, g, s // bq, t // bk)

    kernel = functools.partial(
        _flash_kernel,
        bq=bq, bk=bk, seq_q=s, seq_kv=t,
        causal=causal, window=window, softcap=softcap,
        scale=d ** -0.5,
    )
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # q: (B,S,H,D) → tile (bq, D) at (batch, q-block, head)
            pl.BlockSpec(
                (None, bq, None, d),
                lambda bb, kk, gg, ii, jj: (bb, ii, kk * g + gg, 0),
            ),
            # k/v: (B,T,K,D) → tile (bk, D) at (batch, kv-block, kv-head)
            pl.BlockSpec(
                (None, bk, None, d),
                lambda bb, kk, gg, ii, jj: (bb, jj, kk, 0),
            ),
            pl.BlockSpec(
                (None, bk, None, d),
                lambda bb, kk, gg, ii, jj: (bb, jj, kk, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (None, bq, None, d),
            lambda bb, kk, gg, ii, jj: (bb, ii, kk * g + gg, 0),
        ),
        out_shape=jax.ShapeDtypeStruct((b, s, h, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # m (running max)
            pltpu.VMEM((bq, 128), jnp.float32),   # l (running denom)
            pltpu.VMEM((bq, d), jnp.float32),     # acc
        ],
        interpret=interpret,
    )
    return out(q, k, v)
