"""Dispatching wrapper for attention.

``impl='xla'`` (default) → the chunked online-softmax jnp path in
``repro.models.attention`` (portable; used by dry-runs / CPU);
``impl='pallas'`` → the TPU flash kernel; ``impl='pallas_interpret'`` →
the kernel body interpreted on CPU (correctness)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from . import kernel as _kernel
from . import ref as _ref

__all__ = ["attention", "set_default_impl"]

_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _IMPL
    if impl not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(impl)
    _IMPL = impl


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    impl: Optional[str] = None,
    kv_chunk: int = 1024,
) -> jax.Array:
    impl = impl or _IMPL
    if impl == "xla":
        from ...models.attention import chunked_attention

        b, s = q.shape[0], q.shape[1]
        t = k.shape[1]
        qpos = jnp.broadcast_to(jnp.arange(t - s, t, dtype=jnp.int32), (b, s))
        kpos = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
        return chunked_attention(q, k, v, qpos, kpos, window=window,
                                 softcap=softcap, kv_chunk=kv_chunk)
    return _kernel.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        interpret=(impl == "pallas_interpret"),
    )
