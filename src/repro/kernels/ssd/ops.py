"""Dispatching wrapper for the SSD primitive.

``impl='xla'`` (default) runs the chunked pure-jnp oracle — the portable
path used by training, dry-runs and CPU tests. ``impl='pallas'`` runs the
TPU Pallas kernel; ``impl='pallas_interpret'`` runs the same kernel body
in interpreter mode (CPU correctness validation).
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax

from . import ref

__all__ = ["ssd"]

_IMPL = "xla"


def set_default_impl(impl: str) -> None:
    global _IMPL
    if impl not in ("xla", "pallas", "pallas_interpret"):
        raise ValueError(impl)
    _IMPL = impl


def ssd(
    x: jax.Array,
    dt: jax.Array,
    a: jax.Array,
    b_mat: jax.Array,
    c_mat: jax.Array,
    chunk: int = 256,
    d_skip: Optional[jax.Array] = None,
    impl: Optional[str] = None,
) -> jax.Array:
    impl = impl or _IMPL
    if impl == "xla":
        return ref.ssd_reference(x, dt, a, b_mat, c_mat, chunk=chunk,
                                 d_skip=d_skip)
    from . import kernel
    return kernel.ssd_pallas(
        x, dt, a, b_mat, c_mat, chunk=chunk, d_skip=d_skip,
        interpret=(impl == "pallas_interpret"),
    )
