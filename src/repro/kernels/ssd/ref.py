"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) primitive.

Implements the chunked SSD algorithm of Dao & Gu [arXiv:2405.21060]:
within a chunk the recurrence is computed in its "attention-like" dual
form (quadratic in the chunk length), across chunks a linear state
recurrence carries (H, P, N) states. This file is the correctness oracle
for the Pallas kernel in ``kernel.py`` and the default XLA execution
path used by the model (`repro.models.ssm`).

Recurrence (per head h, with Δ = dt):
    s_t = exp(Δ_t A) s_{t-1} + Δ_t B_t x_tᵀ           s ∈ R^{P×N}
    y_t = C_tᵀ s_t + D x_t
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["ssd_reference", "ssd_sequential", "ssd_decode_step"]


def _segsum(x: jax.Array) -> jax.Array:
    """Stable "segment sum": out[..., i, j] = sum_{j < k <= i} x[..., k]
    for i >= j, -inf otherwise. x: (..., Q)."""
    q = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_reference(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)           (already softplus'd, > 0)
    a: jax.Array,       # (H,)                (negative decay rates)
    b_mat: jax.Array,   # (B, L, G, N)
    c_mat: jax.Array,   # (B, L, G, N)
    chunk: int = 256,
    d_skip: Optional[jax.Array] = None,   # (H,) skip connection
    initial_state: Optional[jax.Array] = None,  # (B, H, P, N)
    return_final_state: bool = False,
) -> jax.Array | Tuple[jax.Array, jax.Array]:
    """Chunked SSD forward. G (B/C groups) broadcasts over H (H % G == 0)."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    l_orig = l
    if l % chunk != 0:
        # pad the tail: dt=0 ⇒ decay=1 and no state contribution, so the
        # final state is unaffected; padded outputs are sliced off.
        pad = chunk - l % chunk
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        l = l + pad
    nc = l // chunk
    rep = h // g

    f32 = jnp.float32
    xc = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    bc = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(f32)
    cc = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, n), rep, axis=3).astype(f32)

    da = dtc * a.astype(f32)[None, None, None, :]          # (B,nc,Q,H)
    cum = jnp.cumsum(da, axis=2)                           # (B,nc,Q,H)

    # ---- intra-chunk (dual / attention-like form) ----
    seg = _segsum(jnp.moveaxis(da, -1, 2))                 # (B,nc,H,Q,Q)
    decay = jnp.exp(seg)
    scores = jnp.einsum("bzihn,bzjhn->bzhij", cc, bc)      # (B,nc,H,Q,Q)
    dt_j = jnp.moveaxis(dtc, -1, 2)[:, :, :, None, :]      # (B,nc,H,1,Q)
    gate = decay * scores * dt_j
    # gate[..., i, j] = decay_ij * (C_i·B_j) * dt_j
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", gate, xc)   # (B,nc,Q,H,P)

    # ---- inter-chunk state recurrence ----
    # chunk-local final states: S_z = sum_j exp(cum_last - cum_j) dt_j B_j x_jᵀ
    last = cum[:, :, -1:, :]                               # (B,nc,1,H)
    w = jnp.exp(last - cum) * dtc                          # (B,nc,Q,H)
    s_local = jnp.einsum("bzjh,bzjhp,bzjhn->bzhpn", w, xc, bc)
    chunk_decay = jnp.exp(last[:, :, 0, :])                # (B,nc,H)

    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), f32)
    )

    def scan_body(s_prev, z):
        dec, s_loc = z                                     # (B,H), (B,H,P,N)
        s_new = s_prev * dec[..., None, None] + s_loc
        return s_new, s_prev

    dec_z = jnp.moveaxis(chunk_decay, 1, 0)                # (nc,B,H)
    sl_z = jnp.moveaxis(s_local, 1, 0)                     # (nc,B,H,P,N)
    s_final, s_prevs = jax.lax.scan(scan_body, s0, (dec_z, sl_z))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                  # (B,nc,H,P,N)

    # y_inter_i = exp(cum_i) * C_i · S_prev
    y_inter = jnp.einsum(
        "bzih,bzihn,bzhpn->bzihp", jnp.exp(cum), cc, s_prevs
    )

    y = (y_intra + y_inter).reshape(bsz, l, h, p)
    if d_skip is not None:
        y = y + d_skip.astype(f32)[None, None, :, None] * x.astype(f32)
    y = y[:, :l_orig].astype(x.dtype)
    if return_final_state:
        return y, s_final.astype(jnp.float32)
    return y


def ssd_sequential(
    x, dt, a, b_mat, c_mat, d_skip=None, initial_state=None,
    return_final_state: bool = False,
):
    """Token-by-token recurrence — the independent (slow) oracle used to
    validate the chunked form."""
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    rep = h // g
    f32 = jnp.float32
    bb = jnp.repeat(b_mat, rep, axis=2).astype(f32)
    cb = jnp.repeat(c_mat, rep, axis=2).astype(f32)
    s = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((bsz, h, p, n), f32)
    )

    def body(s, z):
        x_t, dt_t, b_t, c_t = z                            # (B,H,P),(B,H),(B,H,N),(B,H,N)
        dec = jnp.exp(dt_t * a.astype(f32))                # (B,H)
        s = s * dec[..., None, None] + jnp.einsum(
            "bh,bhp,bhn->bhpn", dt_t, x_t, b_t
        )
        y_t = jnp.einsum("bhn,bhpn->bhp", c_t, s)
        return s, y_t

    xs = (
        jnp.moveaxis(x.astype(f32), 1, 0),
        jnp.moveaxis(dt.astype(f32), 1, 0),
        jnp.moveaxis(bb, 1, 0),
        jnp.moveaxis(cb, 1, 0),
    )
    s_final, ys = jax.lax.scan(body, s, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if d_skip is not None:
        y = y + d_skip.astype(f32)[None, None, :, None] * x.astype(f32)
    y = y.astype(x.dtype)
    if return_final_state:
        return y, s_final
    return y


def ssd_decode_step(
    x_t: jax.Array,     # (B, H, P)
    dt_t: jax.Array,    # (B, H)
    a: jax.Array,       # (H,)
    b_t: jax.Array,     # (B, G, N)
    c_t: jax.Array,     # (B, G, N)
    state: jax.Array,   # (B, H, P, N) fp32
    d_skip: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence for serving."""
    bsz, h, p = x_t.shape
    g = b_t.shape[1]
    rep = h // g
    f32 = jnp.float32
    bb = jnp.repeat(b_t, rep, axis=1).astype(f32)
    cb = jnp.repeat(c_t, rep, axis=1).astype(f32)
    dec = jnp.exp(dt_t.astype(f32) * a.astype(f32))
    state = state * dec[..., None, None] + jnp.einsum(
        "bh,bhp,bhn->bhpn", dt_t.astype(f32), x_t.astype(f32), bb
    )
    y = jnp.einsum("bhn,bhpn->bhp", cb, state)
    if d_skip is not None:
        y = y + d_skip.astype(f32)[None, :, None] * x_t.astype(f32)
    return y.astype(x_t.dtype), state
