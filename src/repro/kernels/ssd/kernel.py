"""Pallas TPU kernel for the Mamba-2 SSD primitive (chunked scan).

TPU adaptation of the SSD algorithm [arXiv:2405.21060]: the GPU
implementation leans on warp-level parallel scans; on TPU we instead
exploit the sequential minor-to-major grid order — the grid is
``(batch, head, L/Q)`` with the chunk index innermost, and the running
(P, N) state lives in VMEM scratch, carried across chunk iterations for
a fixed (batch, head). Within a chunk the dual quadratic form runs on
the MXU ((Q, N)·(N, Q) and (Q, Q)·(Q, P) matmuls); across chunks the
state update is a rank-Q outer-product accumulation — exactly the
structure the systolic array wants, no warp shuffles required.

B/C are pre-broadcast from groups to heads by the ops wrapper so the
kernel sees per-head (Q, N) tiles.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref,      # (Q, P)
    dt_ref,     # (Q, 1)
    a_ref,      # (1, 1)   per-head decay rate
    b_ref,      # (Q, N)
    c_ref,      # (Q, N)
    d_ref,      # (1, 1)   skip coefficient
    y_ref,      # (Q, P)
    state_ref,  # scratch (P, N) f32
    *,
    q_chunk: int,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)          # (Q, P)
    dt = dt_ref[...].astype(jnp.float32)[:, 0]  # (Q,)
    a = a_ref[0, 0].astype(jnp.float32)
    bmat = b_ref[...].astype(jnp.float32)       # (Q, N)
    cmat = c_ref[...].astype(jnp.float32)       # (Q, N)

    da = dt * a                                 # (Q,)
    cum = jnp.cumsum(da)                        # (Q,)

    # ---- intra-chunk dual form ----
    seg = cum[:, None] - cum[None, :]           # (Q, Q) = cum_i - cum_j
    rows = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q_chunk, q_chunk), 1)
    decay = jnp.where(rows >= cols, jnp.exp(seg), 0.0)
    scores = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (Q, Q) = C_i · B_j
    gate = decay * scores * dt[None, :]
    y = jax.lax.dot_general(
        gate, x, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (Q, P)

    # ---- inter-chunk: contribution of the carried state ----
    # y_inter_i = exp(cum_i) * C_i · S_prevᵀ  → (Q,N)·(N,P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        cmat, state_ref[...], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    # ---- state update: S = S·exp(cum_last) + Σ_j w_j x_j B_jᵀ ----
    w = jnp.exp(cum[-1] - cum) * dt              # (Q,)
    outer = jax.lax.dot_general(
        x * w[:, None], bmat, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                            # (P, N)
    state_ref[...] = state_ref[...] * jnp.exp(cum[-1]) + outer

    # ---- skip connection + write ----
    y = y + d_ref[0, 0].astype(jnp.float32) * x
    y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret")
)
def ssd_pallas(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)
    a: jax.Array,       # (H,)
    b_mat: jax.Array,   # (B, L, G, N)
    c_mat: jax.Array,   # (B, L, G, N)
    chunk: int = 128,
    d_skip: Optional[jax.Array] = None,
    interpret: bool = False,
) -> jax.Array:
    bsz, l, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    if l % chunk != 0:
        raise ValueError(f"L {l} must divide chunk {chunk}")
    rep = h // g
    bb = jnp.repeat(b_mat, rep, axis=2)          # (B, L, H, N)
    cb = jnp.repeat(c_mat, rep, axis=2)
    dt3 = dt[..., None]                          # (B, L, H, 1)
    a2 = a.reshape(h, 1)
    d2 = (d_skip if d_skip is not None else jnp.zeros((h,), jnp.float32)).reshape(h, 1)

    grid = (bsz, h, l // chunk)
    kernel = functools.partial(_ssd_kernel, q_chunk=chunk)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, None, p),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),   # x
            pl.BlockSpec((None, chunk, None, 1),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),   # dt
            pl.BlockSpec((1, 1),
                         lambda bi, hi, ci: (hi, 0)),           # a
            pl.BlockSpec((None, chunk, None, n),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),   # B
            pl.BlockSpec((None, chunk, None, n),
                         lambda bi, hi, ci: (bi, ci, hi, 0)),   # C
            pl.BlockSpec((1, 1),
                         lambda bi, hi, ci: (hi, 0)),           # d_skip
        ],
        out_specs=pl.BlockSpec((None, chunk, None, p),
                               lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, l, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )
    return out(x, dt3, a2, bb, cb, d2)
