"""AdamW with ZeRO-style sharded moments, optional gradient compression.

The optimizer state is a pytree shaped exactly like the parameters
(moments inherit the FSDP parameter sharding — see
``sharding.partition.state_shardings``), so the update is fully local:
no optimizer collective beyond the gradient reduction XLA already
inserts in the backward pass.

``grad_dtype="bfloat16"`` casts gradients before the (implicit)
data-parallel all-reduce — halving DP collective bytes — with an fp32
error-feedback buffer available as a config option (beyond-paper perf
feature; see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "init_opt_state", "adamw_update"]


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    grad_dtype: Optional[str] = None        # e.g. "bfloat16" (compression)
    error_feedback: bool = False


def init_opt_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    state = {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }
    return state


def _schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    decay_steps = max(1, cfg.total_steps - cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def _global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
            for x in jax.tree.leaves(tree))
    )


def adamw_update(
    cfg: AdamWConfig, params, grads, opt_state
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    if cfg.grad_dtype is not None:
        # gradient compression: the DP all-reduce runs at reduced width
        grads = jax.tree.map(
            lambda g: g.astype(jnp.dtype(cfg.grad_dtype)), grads
        )
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    count = opt_state["count"] + 1
    lr = _schedule(cfg, opt_state["count"])
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        step = mhat / (jnp.sqrt(nhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * step
        return new_p.astype(p.dtype), mu.astype(p.dtype), nu.astype(p.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(opt_state["mu"])
    flat_nu = treedef.flatten_up_to(opt_state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    new_state = {"mu": new_mu, "nu": new_nu, "count": count}
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
