"""Roofline analysis from compiled XLA artifacts (no hardware required).

Per (arch × shape × mesh) the dry-run supplies:
  * ``compiled.cost_analysis()`` → per-device HLO FLOPs / bytes accessed,
  * ``compiled.as_text()``       → post-SPMD HLO, scanned for collective
    ops (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) whose result-shape bytes we sum per category,
  * ``compiled.memory_analysis()`` → per-device footprint (fits-HBM proof).

Three roofline terms (seconds, per step, per device):
    compute    = FLOPs / peak_FLOPs            (197 TFLOP/s bf16, v5e)
    memory     = bytes / HBM_bw                (819 GB/s)
    collective = collective_bytes / link_bw    (~50 GB/s/link ICI)

Conventions: the compiled module is the per-device SPMD program, so all
counts are per-device; collective bytes use the op *result* shard size
(≈ traffic through each device's links; calibrated in
tests/test_roofline_calibration.py and consistent across perf
iterations, which is what the §Perf loop needs).
"""

from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Optional

from ..core.backends.analytical import HardwareSpec, TPU_V5E

__all__ = [
    "CollectiveStats",
    "RooflineReport",
    "collective_bytes_from_hlo",
    "build_report",
]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# result type(s) then op name, e.g.
#   %ag = bf16[8,128]{1,0} all-gather(...)
#   %ar = (f32[4], f32[4]) all-reduce-start(...)
_OP_RE = re.compile(
    r"=\s*(?P<types>\([^=]*?\)|\S+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\("
)
_SHAPE_RE = re.compile(r"([a-z]+\d*)\[([\d,]*)\]")


def _type_bytes(types: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(types):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)
    count_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveStats:
    """Sum result-shape bytes of every collective in a post-SPMD module.
    Async pairs are counted once (``-start`` only; bare ops as-is)."""
    stats = CollectiveStats()
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue
        kind = m.group("op")
        nbytes = _type_bytes(m.group("types"))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device, per-step
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collective_detail: Dict[str, int]
    collective_count: int
    model_flops: float          # useful model FLOPs per device per step
    # memory analysis (bytes per device)
    peak_memory: Optional[float] = None
    argument_size: Optional[float] = None
    output_size: Optional[float] = None
    temp_size: Optional[float] = None
    hw: HardwareSpec = TPU_V5E

    # ---- derived terms (seconds) ----
    @property
    def compute_s(self) -> float:
        return self.flops / self.hw.peak_flops

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / self.hw.hbm_bw

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / self.hw.ici_bw

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        """Bound model: overlapped compute/HBM, exposed collectives."""
        return max(self.compute_s, self.memory_s) + self.collective_s

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / HLO FLOPs — catches remat/redundancy waste."""
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Achievable fraction of peak on the bound model: useful FLOPs
        over peak·step-time. Meaningful for train/prefill; decode steps
        are bandwidth-bound by definition — see ``bound_fraction``."""
        if self.step_s <= 0:
            return 0.0
        return (self.model_flops / self.hw.peak_flops) / self.step_s

    @property
    def bound_fraction(self) -> float:
        """Dominant-term share of the modeled step: 1.0 = the step is
        purely its own roofline bound with everything else hidden. The
        per-cell optimization target for bandwidth-bound (decode) cells."""
        if self.step_s <= 0:
            return 0.0
        return max(self.compute_s, self.memory_s, self.collective_s) / self.step_s

    def step_model(self):
        """Bridge to the analytical execution model: a per-device
        :class:`~repro.core.backends.analytical.StepModel` carrying this
        report's roofline estimates. Feed it (or the report itself — both
        expose ``model_flops``/``hw``) to ``TalpMonitor(flop_model=...)``
        so the runtime's measured Computational Efficiency uses the same
        FLOP model the static analysis does."""
        from ..core.backends.analytical import StepModel

        return StepModel(
            flops=self.flops,
            hbm_bytes=self.hbm_bytes,
            collective_bytes=self.collective_bytes,
            model_flops=self.model_flops,
            hw=self.hw,
        )

    def to_dict(self) -> Dict:
        d = {
            k: v for k, v in asdict(self).items() if k != "hw"
        }
        d.update(
            compute_s=self.compute_s,
            memory_s=self.memory_s,
            collective_s=self.collective_s,
            dominant=self.dominant,
            step_s=self.step_s,
            useful_flop_ratio=self.useful_flop_ratio,
            roofline_fraction=self.roofline_fraction,
            bound_fraction=self.bound_fraction,
        )
        return d

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2)


def build_report(
    arch: str,
    shape: str,
    mesh_desc: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    model_flops_global: float,
    memory_analysis=None,
    hw: HardwareSpec = TPU_V5E,
) -> RooflineReport:
    stats = collective_bytes_from_hlo(hlo_text)
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    rep = RooflineReport(
        arch=arch,
        shape=shape,
        mesh=mesh_desc,
        chips=chips,
        flops=flops,
        hbm_bytes=hbm,
        collective_bytes=float(stats.total_bytes),
        collective_detail=dict(stats.bytes_by_kind),
        collective_count=stats.total_count,
        model_flops=model_flops_global / chips,
        hw=hw,
    )
    if memory_analysis is not None:
        for attr, key in (
            ("peak_memory", "peak_memory_in_bytes"),
            ("argument_size", "argument_size_in_bytes"),
            ("output_size", "output_size_in_bytes"),
            ("temp_size", "temp_size_in_bytes"),
        ):
            val = getattr(memory_analysis, key, None)
            if val is not None:
                setattr(rep, attr, float(val))
    return rep
