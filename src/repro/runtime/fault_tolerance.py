"""Fault-tolerance runtime: step heartbeats, straggler detection, and a
checkpointed restart loop.

At thousand-node scale the failure model is: (a) hard node loss →
process exit → restart from the last checkpoint (possibly on fewer
nodes: elastic reshard, see checkpoint.checkpointer.restore_checkpoint),
(b) soft stragglers → step-time outliers → flagged by the
``StragglerDetector`` so the deployment layer can re-slice. Both hooks
are exercised by tests (failure injection + elastic restore); the
TALP host timeline separately accounts the recovery time as non-useful,
which is how the paper's metrics make failure overheads visible.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional

__all__ = ["Heartbeat", "StragglerDetector", "run_with_restarts",
           "FaultToleranceReport"]


class Heartbeat:
    """Tracks liveness: the deployment layer polls ``age()`` and declares
    the worker dead past a deadline."""

    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.last_beat: Optional[float] = None
        self.count = 0

    def beat(self) -> None:
        self.last_beat = self.clock()
        self.count += 1

    def age(self) -> float:
        if self.last_beat is None:
            return float("inf")
        return self.clock() - self.last_beat

    def alive(self, deadline: float) -> bool:
        return self.age() <= deadline


@dataclass
class StragglerDetector:
    """Flags step-time outliers vs a trailing median (soft-failure signal).

    ``factor=2.0`` → a step slower than 2× the trailing median is a
    straggler event. Mitigation at scale: the caller re-slices or drops
    the slow host; here we record and expose the events."""

    window: int = 20
    factor: float = 2.0
    times: List[float] = field(default_factory=list)
    events: List[int] = field(default_factory=list)

    def observe(self, step: int, duration: float) -> bool:
        hist = self.times[-self.window:]
        self.times.append(duration)
        if len(hist) < 5:
            return False
        median = sorted(hist)[len(hist) // 2]
        if duration > self.factor * median:
            self.events.append(step)
            return True
        return False


@dataclass
class FaultToleranceReport:
    restarts: int = 0
    resumed_steps: List[int] = field(default_factory=list)
    straggler_events: List[int] = field(default_factory=list)


def run_with_restarts(
    run_fn: Callable[[int], int],
    max_restarts: int = 3,
    on_restart: Optional[Callable[[int, BaseException], None]] = None,
) -> FaultToleranceReport:
    """Restart loop: ``run_fn(attempt)`` trains from its checkpointed
    state and returns the final step; exceptions trigger restore+retry
    (the single-controller analogue of a cluster-manager restart)."""
    report = FaultToleranceReport()
    attempt = 0
    while True:
        try:
            run_fn(attempt)
            return report
        except KeyboardInterrupt:
            raise
        except BaseException as e:
            attempt += 1
            report.restarts += 1
            if on_restart is not None:
                on_restart(attempt, e)
            if attempt > max_restarts:
                raise
