"""Quickstart: the paper's TALP metrics in 60 seconds.

1. Build a synthetic accelerated-application trace (PILS-style).
2. Compute the paper's host + device efficiency hierarchies (eqs. 6-12).
3. Render the paper-style text report and JSON.
4. Monitor *live* JAX execution with TalpMonitor (CUPTI-analogue).
5. Export the monitored run as a Chrome/Perfetto trace (open it at
   ui.perfetto.dev) and validate it structurally.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import TalpMonitor, analyze_trace
from repro.core.backends import RuntimeBackend, SyntheticTraceBuilder
from repro.core.report import render_tables, render_text, to_json

# --- 1-3: synthetic trace → metrics → report ------------------------------
b = SyntheticTraceBuilder(nranks=2, ndevices=2, name="quickstart")
for _ in range(3):
    b.rank(0).useful(0.2).offload_kernel(1.0).offload_memory(0.1)
    b.rank(1).useful(0.2).offload_kernel(0.7).offload_memory(0.1)
    b.barrier()  # rank 1 waits in MPI for rank 0
trace = b.build()

analysis = analyze_trace(trace)
analysis.validate()          # multiplicative hierarchy: PE = LB × CE × OE
print(render_text(analysis, title="synthetic PILS-style pattern"))
print()
host = analysis.host
print(f"Host  PE = MPI_PE × Offload_Eff = "
      f"{host.mpi_parallel_efficiency:.3f} × "
      f"{host.device_offload_efficiency:.3f} = "
      f"{host.parallel_efficiency:.3f}")

# --- 4: live monitoring of real JAX work -----------------------------------
backend = RuntimeBackend()
mon = TalpMonitor("live", backend=backend)
step = jax.jit(lambda x: jnp.tanh(x @ x).sum())
x = jnp.ones((512, 512))

with mon.region("compute"):
    for i in range(5):
        h = backend.launch(step, x, name=f"step{i}")  # async dispatch
        _ = sum(j * j for j in range(20000))          # host useful work
        with mon.offload():
            backend.wait(h)                           # blocked on device

result = mon.finalize()
print()
print(render_tables(result))
print()
print("JSON output (truncated):")
print(to_json(result)[:400], "...")

# --- 5: Chrome/Perfetto trace export ---------------------------------------
# The same monitored run as a trace-event file: host/device lanes, exact
# region markers, and (with a TelemetryExporter attached) counter tracks
# of the sampled hierarchy metrics. Drop it on ui.perfetto.dev.
from repro.core.telemetry.traceexport import export_monitor, validate_chrome_trace

trace_json = export_monitor(mon, result=result)
summary = validate_chrome_trace(trace_json)   # same checker tests/CI use
with open("/tmp/quickstart_trace.json", "w") as f:
    f.write(trace_json)
print()
print(f"wrote Chrome trace: /tmp/quickstart_trace.json "
      f"({summary['n_events']} events, lanes {summary['lanes']})")
