"""End-to-end driver: train a reduced llama3.2-family model for a few
hundred steps on CPU with the full production substrate — TALP
monitoring, background-prefetch data pipeline, async checkpointing with
restart, straggler detection — then print the TALP report and the loss
curve summary.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import tempfile

import numpy as np

from repro.configs import smoke_config
from repro.launch.train import train
from repro.optim.adamw import AdamWConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="llama3.2-3b")
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    ckpt = tempfile.mkdtemp(prefix="talp_train_")
    state, history, talp = train(
        cfg,
        steps=args.steps,
        global_batch=8,
        seq_len=128,
        ckpt_dir=ckpt,
        ckpt_every=50,
        talp_interval=50,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps),
    )
    losses = [h["loss"] for h in history]
    print(f"\nloss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({len(losses)} steps, checkpoints in {ckpt})")
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0], "loss must decrease on the synthetic task"
    print("OK: loss decreased; TALP report above.")


if __name__ == "__main__":
    main()
