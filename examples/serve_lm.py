"""End-to-end serving example: batched prefill → decode with the split
(prefix + hot-ring) KV cache, TALP-monitored, for a hybrid SSM+attention
architecture (zamba2 family).

Run:  PYTHONPATH=src python examples/serve_lm.py [--gen-len 24]
"""

import argparse

from repro.configs import smoke_config
from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="zamba2-2.7b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=12)
    args = ap.parse_args()

    cfg = smoke_config(args.arch)
    tokens, talp = serve(cfg, requests=args.requests,
                         prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"generated token matrix: {tokens.shape} "
          f"(requests × new tokens)")
    decode = talp.regions["decode"]
    print(f"decode-region Device Offload Eff.: "
          f"{decode.host.device_offload_efficiency:.3f}")


if __name__ == "__main__":
    main()
