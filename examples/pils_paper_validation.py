"""Reproduce the paper's seven PILS use cases (Figs. 4-10) and print the
TALP report for each — the values match the paper (see
tests/test_pils_usecases.py for the assertions).

Run:  PYTHONPATH=src python examples/pils_paper_validation.py
"""

from repro.core.report import render_text
from repro.pils import USE_CASES, run_use_case

for name in sorted(USE_CASES):
    res = run_use_case(name)
    print("#" * 72)
    print(f"# {name}: {res.description}")
    print("#" * 72)
    for variant, analysis in res.analyses.items():
        title = f"{name} ({variant})" if len(res.analyses) > 1 else name
        print(render_text(analysis, title=title))
        print()
