"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family and run one train step + one prefill→decode step on CPU, asserting
output shapes and no NaNs. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_config, smoke_config
from repro.models import (
    decode_step,
    init_decode_caches,
    init_params,
    prefill,
    train_loss,
)
from repro.models.lm import grow_caches

BATCH, SEQ = 2, 64


def _batch(cfg, key):
    kt, kl = jax.random.split(key)
    labels = jax.random.randint(kl, (BATCH, SEQ), 0, cfg.vocab_size, jnp.int32)
    if cfg.frontend == "token":
        inputs = jax.random.randint(kt, (BATCH, SEQ), 0, cfg.vocab_size,
                                    jnp.int32)
    else:
        inputs = jax.random.normal(kt, (BATCH, SEQ, cfg.d_model),
                                   jnp.bfloat16)
    return {"inputs": inputs, "labels": labels}


@pytest.fixture(scope="module", params=ALL_ARCHS)
def arch(request):
    return request.param


@pytest.fixture(scope="module")
def setup(arch):
    cfg = smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    return cfg, params, _batch(cfg, jax.random.PRNGKey(1))


def test_full_config_exists(arch):
    cfg = get_config(arch)
    assert cfg.name == arch
    assert cfg.num_layers % len(cfg.pattern) == 0
    assert cfg.n_params() > 0


def test_train_step_shapes_and_finite(setup):
    cfg, params, batch = setup
    loss, metrics = jax.jit(lambda p, b: train_loss(cfg, p, b))(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{cfg.name}: loss not finite"
    assert float(metrics["tokens"]) == BATCH * SEQ


def test_train_grads_finite(setup):
    cfg, params, batch = setup
    grads = jax.jit(
        jax.grad(lambda p, b: train_loss(cfg, p, b)[0])
    )(params, batch)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
               for g in flat), f"{cfg.name}: non-finite grads"
    # gradient reaches every parameter group (no dead branches)
    norms = [float(jnp.linalg.norm(g.astype(jnp.float32))) for g in flat]
    assert sum(n > 0 for n in norms) >= len(norms) * 0.5


def test_prefill_then_decode(setup):
    cfg, params, batch = setup
    logits, caches, pos = jax.jit(lambda p, x: prefill(cfg, p, x))(
        params, batch["inputs"]
    )
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    if cfg.frontend == "token":
        tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1)[:, None]
        tok = tok.astype(jnp.int32)
    else:
        tok = jnp.zeros((BATCH, 1, cfg.d_model), jnp.bfloat16)
    logits2, caches2, pos2 = jax.jit(
        lambda p, t, q, c: decode_step(cfg, p, t, q, c)
    )(params, tok, pos, caches)
    assert logits2.shape == (BATCH, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert np.all(np.asarray(pos2) == SEQ + 1)


def test_fresh_decode_caches(setup):
    """Decode against an init_decode_caches(filled=True) cache — the
    serve_step the decode dry-run shapes lower."""
    cfg, params, _ = setup
    caches = init_decode_caches(cfg, BATCH, cache_len=SEQ, filled=True)
    pos = jnp.full((BATCH,), SEQ, jnp.int32)
    if cfg.frontend == "token":
        tok = jnp.zeros((BATCH, 1), jnp.int32)
    else:
        tok = jnp.zeros((BATCH, 1, cfg.d_model), jnp.bfloat16)
    logits, new_caches, pos2 = jax.jit(
        lambda p, t, q, c: decode_step(cfg, p, t, q, c)
    )(params, tok, pos, caches)
    assert logits.shape == (BATCH, cfg.padded_vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # cache structure is preserved
    assert jax.tree.structure(caches) == jax.tree.structure(new_caches)


def test_decode_matches_prefill_continuation(setup):
    """Greedy decode from a prefix equals teacher-forced prefill logits
    (cache correctness)."""
    cfg, params, batch = setup
    if cfg.frontend != "token":
        pytest.skip("embed-frontend archs: continuation uses embeddings")
    full = batch["inputs"]                       # (B, S)
    half = SEQ // 2
    # prefill on the first half, then grow the cache for decoding
    _, caches, pos = prefill(cfg, params, full[:, :half])
    caches = grow_caches(cfg, caches, half + 4)
    # decode the second half token by token, teacher forcing
    outs = []
    for t in range(half, min(half + 4, SEQ)):
        logits, caches, pos = decode_step(
            cfg, params, full[:, t: t + 1], pos, caches
        )
        outs.append(logits)
    # reference: full prefill gives the same last-position logits
    ref_logits, _, _ = prefill(cfg, params, full[:, : half + 4])
    np.testing.assert_allclose(
        np.asarray(outs[-1], np.float32),
        np.asarray(ref_logits, np.float32),
        rtol=0.15, atol=0.15,
    )
