"""Analytical backend: roofline-derived device states → paper metrics,
plus the beyond-paper Device Computational Efficiency branch."""

import pytest

from repro.core.analysis import analyze_trace
from repro.core.backends import HardwareSpec, StepModel, TPU_V5E, trace_from_step_model
from repro.core.backends.analytical import AnalyticalBackend
from repro.core.report import node_scan_table


def test_step_model_terms():
    m = StepModel(flops=197e12, hbm_bytes=819e9, collective_bytes=50e9)
    assert m.compute_s == pytest.approx(1.0)
    assert m.hbm_s == pytest.approx(1.0)
    assert m.collective_s == pytest.approx(1.0)
    assert m.kernel_s == pytest.approx(1.0)   # max(compute, hbm)
    assert m.memory_s == pytest.approx(1.0)


def test_compute_bound_vs_memory_bound():
    hw = HardwareSpec()
    cb = StepModel(flops=2 * 197e12, hbm_bytes=819e9, collective_bytes=0, hw=hw)
    mb = StepModel(flops=197e12, hbm_bytes=4 * 819e9, collective_bytes=0, hw=hw)
    assert cb.kernel_s == pytest.approx(2.0)
    assert mb.kernel_s == pytest.approx(4.0)


def test_balanced_trace_metrics():
    m = StepModel(flops=197e12, hbm_bytes=0.5 * 819e9, collective_bytes=0.25 * 50e9)
    tr = trace_from_step_model([m, m], steps=3)
    a = analyze_trace(tr)
    a.validate()
    assert a.device.load_balance == pytest.approx(1.0)
    # kernel 1.0s, memory 0.25s per step → CE = 1/1.25
    assert a.device.communication_efficiency == pytest.approx(1 / 1.25)
    assert a.device.orchestration_efficiency == pytest.approx(1.0)


def test_imbalanced_devices():
    m_fast = StepModel(flops=0.5 * 197e12, hbm_bytes=0, collective_bytes=0)
    m_slow = StepModel(flops=1.0 * 197e12, hbm_bytes=0, collective_bytes=0)
    tr = trace_from_step_model([m_fast, m_slow], steps=2)
    a = analyze_trace(tr)
    assert a.device.load_balance == pytest.approx(0.75)


def test_host_gap_becomes_idle():
    m = StepModel(flops=197e12, hbm_bytes=0, collective_bytes=0, host_gap_s=1.0)
    tr = trace_from_step_model([m], steps=2, host_useful_s=0.0)
    a = analyze_trace(tr)
    # per step: 1s kernel + 1s gap → orchestration 50%
    assert a.device.orchestration_efficiency == pytest.approx(0.5)


def test_computational_efficiency_extension():
    """Paper's future-work branch: useful FLOPs / peak over kernel time."""
    m = StepModel(flops=2 * 197e12, hbm_bytes=0, collective_bytes=0,
                  model_flops=1 * 197e12)
    assert m.computational_efficiency == pytest.approx(0.5)
    be = AnalyticalBackend([m], steps=1)
    a = be.analyze()
    assert a.device.computational_efficiency == pytest.approx(0.5)
    trees = a.trees()
    node = trees["device"].find("Computational Eff. (ext)")
    assert node is not None and node.value == pytest.approx(0.5)
    trees["device"].validate()  # ext node is non-multiplicative


def test_collective_overlap_knob():
    m0 = StepModel(flops=197e12, hbm_bytes=0, collective_bytes=50e9)
    m1 = StepModel(flops=197e12, hbm_bytes=0, collective_bytes=50e9,
                   collective_overlap=0.75)
    assert m0.memory_s == pytest.approx(1.0)
    assert m1.memory_s == pytest.approx(0.25)
    assert m1.step_s < m0.step_s


def test_node_scan_table_renders():
    rows = []
    for nodes in (1, 2, 4, 8):
        m = StepModel(flops=197e12 / nodes, hbm_bytes=0,
                      collective_bytes=5e9 * nodes)
        rows.append(analyze_trace(trace_from_step_model([m] * 2, steps=1)))
    table = node_scan_table(rows, ["1", "2", "4", "8"], title="scan")
    assert "Orchestration Eff." in table
    assert table.count("\n") >= 8


def test_default_hw_is_v5e():
    assert TPU_V5E.peak_flops == pytest.approx(197e12)
    assert TPU_V5E.hbm_bw == pytest.approx(819e9)
    assert TPU_V5E.ici_bw == pytest.approx(50e9)
