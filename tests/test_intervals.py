"""Unit + property tests for the interval algebra (paper §4.2 post-processing)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import intervals as iv


# ---------------------------------------------------------------------------
# strategies
# ---------------------------------------------------------------------------
@st.composite
def interval_sets(draw, max_n=30, t_max=100.0):
    n = draw(st.integers(0, max_n))
    pairs = []
    for _ in range(n):
        a = draw(st.floats(0, t_max, allow_nan=False, allow_infinity=False))
        b = draw(st.floats(0, t_max, allow_nan=False, allow_infinity=False))
        lo, hi = min(a, b), max(a, b)
        pairs.append((lo, hi))
    return iv.as_intervals(pairs) if pairs else iv.EMPTY.copy()


# ---------------------------------------------------------------------------
# unit tests
# ---------------------------------------------------------------------------
def test_flatten_merges_overlaps():
    out = iv.flatten([(0, 2), (1, 3), (5, 6)])
    np.testing.assert_allclose(out, [[0, 3], [5, 6]])


def test_flatten_merges_touching():
    out = iv.flatten([(0, 1), (1, 2)])
    np.testing.assert_allclose(out, [[0, 2]])


def test_flatten_drops_empty():
    out = iv.flatten([(1, 1), (2, 2)])
    assert len(out) == 0


def test_flatten_streams_example():
    # paper: overlapping launches across streams merge into one interval
    stream0 = [(0.0, 1.0), (2.0, 3.0)]
    stream1 = [(0.5, 2.5)]
    out = iv.flatten(stream0 + stream1)
    np.testing.assert_allclose(out, [[0.0, 3.0]])


def test_subtract_removes_overlap():
    mem = [(0, 4)]
    kern = [(1, 2), (3, 5)]
    out = iv.subtract(mem, kern)
    np.testing.assert_allclose(out, [[0, 1], [2, 3]])


def test_subtract_noop_when_disjoint():
    out = iv.subtract([(0, 1)], [(2, 3)])
    np.testing.assert_allclose(out, [[0, 1]])


def test_gaps_classifies_idle():
    busy = [(1, 2), (3, 4)]
    out = iv.gaps(busy, 0, 5)
    np.testing.assert_allclose(out, [[0, 1], [2, 3], [4, 5]])


def test_intersect():
    out = iv.intersect([(0, 3)], [(1, 2), (2.5, 4)])
    np.testing.assert_allclose(out, [[1, 2], [2.5, 3]])


def test_union():
    out = iv.union([(0, 1)], [(0.5, 2)])
    np.testing.assert_allclose(out, [[0, 2]])


def test_clip():
    out = iv.clip([(0, 10)], 2, 3)
    np.testing.assert_allclose(out, [[2, 3]])


def test_invalid_interval_raises():
    with pytest.raises(ValueError):
        iv.as_intervals([(2, 1)])


def test_total_ignores_double_count():
    assert iv.total([(0, 2), (1, 3)]) == pytest.approx(3.0)


# ---------------------------------------------------------------------------
# property tests (system invariants)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(interval_sets())
def test_flatten_idempotent(a):
    once = iv.flatten(a)
    twice = iv.flatten(once)
    np.testing.assert_allclose(once, twice)
    assert iv.is_flat(once)


@settings(max_examples=200, deadline=None)
@given(interval_sets(), interval_sets())
def test_subtract_intersect_partition(a, b):
    """subtract(a,b) and intersect(a,b) partition flatten(a)."""
    sub = iv.total(iv.subtract(a, b))
    inter = iv.total(iv.intersect(a, b))
    assert sub + inter == pytest.approx(iv.total(a), abs=1e-9)


@settings(max_examples=200, deadline=None)
@given(interval_sets(), interval_sets())
def test_union_inclusion_exclusion(a, b):
    u = iv.total(iv.union(a, b))
    inter = iv.total(iv.intersect(a, b))
    assert u == pytest.approx(iv.total(a) + iv.total(b) - inter, abs=1e-9)


@settings(max_examples=200, deadline=None)
@given(interval_sets())
def test_gaps_complement(a):
    """busy + idle == window span (the device three-state partition)."""
    lo, hi = 0.0, 150.0
    clipped = iv.clip(a, lo, hi)
    idle = iv.gaps(clipped, lo, hi)
    assert iv.total(clipped) + iv.total(idle) == pytest.approx(hi - lo, abs=1e-9)


@settings(max_examples=200, deadline=None)
@given(interval_sets(), interval_sets())
def test_kernel_memory_disjoint_after_postprocess(kern, mem):
    """paper pipeline: memory-after-subtract never overlaps kernels."""
    k = iv.flatten(kern)
    m = iv.subtract(mem, k)
    assert iv.total(iv.intersect(k, m)) == pytest.approx(0.0, abs=1e-12)


# ---------------------------------------------------------------------------
# vectorized engine ≡ scalar reference (bit-for-bit, not approximately:
# both compute the same max/min of the same float64 inputs)
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(interval_sets(), interval_sets())
def test_subtract_matches_loop_reference(a, b):
    got = iv.subtract(a, b)
    ref = iv._subtract_loop(a, b)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(got, ref)


@settings(max_examples=200, deadline=None)
@given(interval_sets(), interval_sets())
def test_intersect_matches_loop_reference(a, b):
    got = iv.intersect(a, b)
    ref = iv._intersect_loop(a, b)
    assert got.shape == ref.shape
    np.testing.assert_array_equal(got, ref)


def test_vectorized_matches_loop_dense_random():
    """Denser randomized sweep than the strategy above: many touching /
    nested / duplicate boundary cases."""
    rng = np.random.default_rng(7)
    for _ in range(300):
        n, m = rng.integers(0, 40, 2)

        def mk(k):
            # integer grid → frequent exact-touch and duplicate endpoints
            s = rng.integers(0, 30, k).astype(np.float64)
            d = rng.integers(0, 5, k).astype(np.float64)
            return np.stack([s, s + d], axis=1) if k else iv.EMPTY.copy()

        a, b = mk(n), mk(m)
        np.testing.assert_array_equal(iv.subtract(a, b), iv._subtract_loop(a, b))
        np.testing.assert_array_equal(iv.intersect(a, b), iv._intersect_loop(a, b))
