"""Real multi-process conformance harness for the TALP collection layer.

Launches N independent ``python -m repro.launch.train`` (or ``serve``)
processes — actual OS processes with their own interpreters, JAX
runtimes and clocks, not threads or in-process simulations — against one
shared spool directory, then hands the spool back to the test for
validation. This is the harness the ROADMAP's "validate on a real
multi-process fleet" open item asks for: the transports get exercised by
genuinely concurrent producers racing on a real filesystem.

Also hosts the ``jax.distributed`` fleet runner used by the (opt-in)
``AllGatherTransport`` conformance test: every rank initializes the
distributed runtime against a shared coordinator and exchanges its
result through the real collective.

Importable from tests (``from mp_harness import ...``) and runnable
standalone for debugging::

    PYTHONPATH=src python tests/mp_harness.py --ranks 3 --spool /tmp/spool
"""

from __future__ import annotations

import os
import socket
import subprocess
import sys
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC_DIR = os.path.join(REPO_ROOT, "src")

#: Tiny-but-real sizes: enough steps for every TALP state to be charged,
#: small enough that a 3-rank fleet finishes in seconds on CPU. The
#: global batch of 6 divides every fleet size the harness is used with
#: (1, 2 and 3 ranks).
SMOKE_ARCH = "llama3.2-3b"
SMOKE_ARGS = ("--steps", "3", "--batch", "6", "--seq", "16")


def fleet_env() -> Dict[str, str]:
    """Subprocess environment: repo sources importable, CPU-only JAX."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC_DIR + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    return env


@dataclass
class RankRun:
    """One finished rank process."""

    rank: int
    returncode: int
    stdout: str
    stderr: str

    @property
    def ok(self) -> bool:
        return self.returncode == 0


@dataclass
class FleetResult:
    runs: List[RankRun] = field(default_factory=list)

    @property
    def ok(self) -> bool:
        return all(r.ok for r in self.runs)

    def failures(self) -> List[RankRun]:
        return [r for r in self.runs if not r.ok]

    def report(self) -> str:
        lines = []
        for r in self.runs:
            lines.append(f"--- rank {r.rank} exit {r.returncode} ---")
            if not r.ok:
                lines.append(r.stdout[-2000:])
                lines.append(r.stderr[-2000:])
        return "\n".join(lines)


def launch_fleet(
    spool_dir: str,
    n_ranks: int = 3,
    driver: str = "repro.launch.train",
    extra_args: Sequence[str] = (),
    per_rank_args: Optional[Dict[int, Sequence[str]]] = None,
    timeout: float = 300.0,
    env_extra: Optional[Dict[str, str]] = None,
) -> FleetResult:
    """Spawn ``n_ranks`` concurrent driver processes sharing one spool.

    Every rank gets ``--rank i --world-size n --talp-spool <dir>`` plus
    the tiny smoke sizes; ``extra_args`` append to every rank,
    ``per_rank_args[i]`` to rank *i* only (how fault-plan flags reach a
    single rank). Processes are launched together and awaited together —
    the ranks genuinely race on the shared spool directory.
    """
    env = fleet_env()
    if env_extra:
        env.update(env_extra)
    procs = []
    for rank in range(n_ranks):
        cmd = [
            sys.executable, "-m", driver, "--arch", SMOKE_ARCH, "--smoke",
            *SMOKE_ARGS,
            "--rank", str(rank), "--world-size", str(n_ranks),
            "--talp-spool", spool_dir,
            *extra_args,
            *(per_rank_args or {}).get(rank, ()),
        ]
        procs.append((rank, subprocess.Popen(
            cmd, env=env, cwd=REPO_ROOT, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )))
    result = FleetResult()
    for rank, proc in procs:
        try:
            out, err = proc.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            out, err = proc.communicate()
            result.runs.append(RankRun(rank, -9, out, err + "\n[timeout]"))
            continue
        result.runs.append(RankRun(rank, proc.returncode, out, err))
    return result


# ---------------------------------------------------------------------------
# jax.distributed allgather fleet
# ---------------------------------------------------------------------------
#: Worker body run by every process of the allgather fleet: initialize
#: the distributed runtime, build a deterministic per-rank result, push
#: it through the *real* collective, write the merged job JSON.
_ALLGATHER_WORKER = r"""
import sys
rank, n_proc, coordinator, out_path = (
    int(sys.argv[1]), int(sys.argv[2]), sys.argv[3], sys.argv[4]
)
import jax
jax.distributed.initialize(
    coordinator_address=coordinator, num_processes=n_proc, process_id=rank
)
from repro.core import DeviceActivity
from repro.core.merge import AllGatherTransport
from repro.core.report import to_json
from repro.core.talp import TalpMonitor

class Clock:
    def __init__(self): self.t = 0.0
    def __call__(self): return self.t
    def advance(self, dt): self.t += dt

clk = Clock()
mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
with mon.region("step"):
    clk.advance(1.0 + rank)
    with mon.offload():
        clk.advance(0.5)
mon.add_device_record(0, DeviceActivity.KERNEL, 0.0, 0.25 * (rank + 1))
job = AllGatherTransport().gather(mon.finalize(), name="job")
with open(out_path, "w") as f:
    f.write(to_json(job))
"""


def free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def launch_allgather_fleet(
    out_dir: str, n_ranks: int = 2, timeout: float = 300.0
) -> FleetResult:
    """Run an N-process ``jax.distributed`` fleet through the real
    ``AllGatherTransport`` collective; each rank writes the job report it
    obtained to ``<out_dir>/job_rank<i>.json`` (every rank must obtain
    the identical merged result)."""
    env = fleet_env()
    coordinator = f"127.0.0.1:{free_port()}"
    procs = []
    for rank in range(n_ranks):
        out_path = os.path.join(out_dir, f"job_rank{rank}.json")
        procs.append((rank, subprocess.Popen(
            [sys.executable, "-c", _ALLGATHER_WORKER, str(rank),
             str(n_ranks), coordinator, out_path],
            env=env, cwd=REPO_ROOT, text=True,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE,
        )))
    result = FleetResult()
    for rank, proc in procs:
        try:
            out, err = proc.communicate(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            out, err = proc.communicate()
            result.runs.append(RankRun(rank, -9, out, err + "\n[timeout]"))
            continue
        result.runs.append(RankRun(rank, proc.returncode, out, err))
    return result


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ranks", type=int, default=3)
    ap.add_argument("--spool", required=True)
    ap.add_argument("--driver", default="repro.launch.train")
    ap.add_argument("--fault-plan", default=None,
                    help="FaultPlan spec forwarded to every rank as "
                         "--talp-fault-plan (JSON, @file, or path)")
    ap.add_argument("--extra", nargs="*", default=[])
    args = ap.parse_args()
    extra = list(args.extra)
    if args.fault_plan:
        extra += ["--talp-fault-plan", args.fault_plan]
    res = launch_fleet(args.spool, n_ranks=args.ranks, driver=args.driver,
                       extra_args=extra)
    print(res.report() or f"all {args.ranks} rank(s) exited 0")
    sys.exit(0 if res.ok else 1)


if __name__ == "__main__":
    main()
