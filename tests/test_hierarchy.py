"""Declarative hierarchy engine: multiplicative-invariant property tests,
JSON round-trips, spec-driven rendering, and online job-level sampling."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DeviceActivity, TalpMonitor
from repro.core.hierarchy import (
    DEVICE,
    HOST,
    POP,
    SCALABILITY,
    Hierarchy,
    MetricSpec,
    StateDurations,
)
from repro.core.merge import (
    FileSpoolTransport,
    merge_results,
    merge_samples,
    talp_result_from_json,
)
from repro.core.report import from_json, node_scan_table, render_metrics, to_json
from repro.core.scalability import scalability_scan

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")

durations = st.lists(st.floats(0.0, 1e3), min_size=1, max_size=16)


# ---------------------------------------------------------------------------
# property: parent = product of children for random non-negative inputs
# ---------------------------------------------------------------------------
@settings(max_examples=200)
@given(durations, st.floats(1e-3, 1e4))
def test_pop_multiplicative_invariant(useful, elapsed):
    frame = POP.compute(StateDurations(elapsed=elapsed, useful=useful))
    frame.validate(tol=1e-9 * max(1.0, frame["parallel_efficiency"]))


@settings(max_examples=200)
@given(durations, durations, st.floats(1e-3, 1e4))
def test_host_multiplicative_invariant(useful, offload, elapsed):
    n = min(len(useful), len(offload))
    frame = HOST.compute(
        StateDurations(elapsed=elapsed, useful=useful[:n], offload=offload[:n])
    )
    frame.validate(tol=1e-9 * max(1.0, frame["parallel_efficiency"]))


@settings(max_examples=200)
@given(durations, durations, st.floats(1e-3, 1e4))
def test_device_multiplicative_invariant(kernel, memory, elapsed):
    n = min(len(kernel), len(memory))
    frame = DEVICE.compute(
        StateDurations(elapsed=elapsed, kernel=kernel[:n], memory=memory[:n])
    )
    frame.validate(tol=1e-9 * max(1.0, frame["parallel_efficiency"]))


def test_scalability_invariant_via_engine():
    monitors = [_run_monitor(rank=r) for r in range(2)]
    results = [m.finalize()["Global"] for m in monitors]
    for p in scalability_scan(results, resources=[1, 2]):
        p.validate()


# ---------------------------------------------------------------------------
# engine mechanics
# ---------------------------------------------------------------------------
def test_compute_matches_facades():
    sd = StateDurations(
        elapsed=4.0, useful=[3.0, 2.0], offload=[0.5, 1.0], mpi=[0.5, 1.0],
        kernel=[2.0, 1.5], memory=[0.5, 0.25],
    )
    hf = HOST.compute(sd)
    df = DEVICE.compute(sd)
    from repro.core import device_metrics, host_metrics

    hm = host_metrics([3.0, 2.0], [0.5, 1.0], [0.5, 1.0], elapsed=4.0)
    dm = device_metrics([2.0, 1.5], [0.5, 0.25], 4.0)
    assert hf.as_dict() == hm.as_dict()
    assert df.as_dict() == dm.as_dict()


def test_formula_dependency_resolution_and_cycle_detection():
    frame = SCALABILITY.compute(
        StateDurations(
            elapsed=2.0,
            extras={"base_elapsed": 4.0, "resources": 2.0,
                    "base_resources": 1.0, "parallel_efficiency": 0.8},
        )
    )
    assert frame["speedup"] == 2.0
    assert frame["global_efficiency"] == 1.0
    assert frame["computational_scalability"] == 1.0 / 0.8

    loop = Hierarchy(
        name="loop", side="X", count_key="n", count=lambda sd: 0,
        root=MetricSpec("a", "A", lambda sd, dep: dep("b"),
                        children=(MetricSpec("b", "B", lambda sd, dep: dep("a")),)),
    )
    with pytest.raises(RuntimeError, match="cycle"):
        loop.compute(StateDurations(elapsed=1.0))


def test_with_child_appears_in_every_output():
    ext = DEVICE.with_child(
        "parallel_efficiency",
        MetricSpec("occupancy", "SM Occupancy",
                   lambda sd, dep: sd.extras.get("occupancy"),
                   multiplicative=False, optional=True),
    )
    sd = StateDurations(elapsed=4.0, kernel=[2.0, 1.5], memory=[0.5, 0.25],
                        extras={"occupancy": 0.5})
    frame = ext.compute(sd)
    frame.validate()  # annotation node excluded from the product
    # text rendering
    text = render_metrics(frame)
    assert "[ext] SM Occupancy" in text
    # JSON layout: optional node after elapsed/count
    keys = list(frame.as_dict())
    assert keys.index("occupancy") > keys.index("n_devices")
    assert frame.as_dict()["occupancy"] == 0.5
    # tree view
    assert frame.tree().find("SM Occupancy (ext)").value == 0.5

    class R:
        host = None
        device = frame

    table = node_scan_table([R()], ["run"], device_hierarchy=ext)
    assert "Parallel Efficiency" in table  # spec-driven rows still render


def test_duplicate_key_rejected():
    with pytest.raises(ValueError, match="already exists"):
        DEVICE.with_child(
            "parallel_efficiency",
            MetricSpec("load_balance", "LB2", lambda sd, dep: 1.0),
        )


# ---------------------------------------------------------------------------
# fixtures: deterministic monitors
# ---------------------------------------------------------------------------
class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _run_monitor(rank=0, n_records=256, incremental=True):
    clk = _Clock()
    mon = TalpMonitor("job", rank=rank, clock=clk, incremental=incremental)
    with mon.region("step"):
        clk.advance(1.0 + 0.25 * rank)
        with mon.offload():
            clk.advance(0.5)
    t = 0.0
    for i in range(n_records):
        kind = DeviceActivity.KERNEL if i % 3 else DeviceActivity.MEMORY
        mon.add_device_record(0, kind, t, t + 0.004)
        t += 0.003
    clk.advance(1.0)
    return mon


# ---------------------------------------------------------------------------
# JSON round-trips of a merged job-level result
# ---------------------------------------------------------------------------
def test_to_json_from_json_bit_for_bit():
    job = merge_results([_run_monitor(r).finalize() for r in range(3)],
                        name="job")
    text = to_json(job)
    # from_json -> dumps reproduces the exact bytes
    assert json.dumps(from_json(text), indent=2) == text
    # full reconstruction (metrics recomputed) -> identical serialization
    assert to_json(talp_result_from_json(text)) == text


# ---------------------------------------------------------------------------
# online sampling: incremental engine + job-level snapshots
# ---------------------------------------------------------------------------
def test_incremental_sampling_matches_full_reflatten():
    a = _run_monitor(n_records=2000, incremental=True)
    b = _run_monitor(n_records=2000, incremental=False)
    assert to_json(a.sample()) == to_json(b.sample())
    # cache hit on unchanged timeline
    assert to_json(a.sample()) == to_json(b.sample())
    # cache invalidation on new records
    for m in (a, b):
        m.add_device_record(0, DeviceActivity.KERNEL, 100.0, 100.5)
    assert to_json(a.sample()) == to_json(b.sample())
    assert to_json(a.sample_result()) == to_json(b.sample_result())


def test_sample_result_is_non_destructive():
    mon = _run_monitor()
    mon.open_region("live")
    mon.clock.advance(0.5)
    snap = mon.sample_result()
    assert set(snap.regions) == {"Global", "step", "live"}
    assert mon._region_stack == ["Global", "live"]  # nothing closed
    snap2 = mon.sample_result()  # repeatable (frozen clock)
    assert to_json(snap) == to_json(snap2)


def test_merge_samples_agrees_with_merge_results_on_finalized_runs():
    results = [_run_monitor(r).finalize() for r in range(3)]
    assert to_json(merge_samples(results, name="job")) == \
        to_json(merge_results(results, name="job"))


def test_sample_spool_roundtrip(tmp_path):
    spool = FileSpoolTransport(str(tmp_path), world_size=3)
    monitors = [_run_monitor(r) for r in range(3)]
    # rank 1 has not published yet: partial merge covers ranks 0 and 2
    for r in (0, 2):
        spool.submit_sample(monitors[r].sample_result(), rank=r)
    assert spool.sampled_ranks() == [0, 2]
    partial = spool.merge_samples(name="job")
    assert partial["Global"].n_ranks == 2
    # snapshots coexist with (and do not pollute) the post-mortem spool
    assert spool.spooled_ranks() == []
    for r in range(3):
        spool.submit_sample(monitors[r].sample_result(), rank=r)
    full = spool.merge_samples(name="job")
    assert full["Global"].n_ranks == 3


def test_region_acc_running_elapsed_matches_windows():
    mon = _run_monitor()
    acc = mon._acc["step"]
    assert acc.closed_total == sum(e - s for s, e in acc.windows)
    with mon.region("step"):
        mon.clock.advance(0.75)
    assert acc.closed_total == sum(e - s for s, e in acc.windows)
    assert acc.elapsed() == acc.closed_total


# ---------------------------------------------------------------------------
# merge CLI error handling
# ---------------------------------------------------------------------------
def _run_cli(*args):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run(
        [sys.executable, "-m", "repro.core.merge", *args],
        capture_output=True, text=True, env=env,
    )


def test_cli_missing_spool_dir(tmp_path):
    proc = _run_cli(str(tmp_path / "nope"))
    assert proc.returncode == 2
    assert "does not exist" in proc.stderr


def test_cli_empty_spool_dir(tmp_path):
    proc = _run_cli(str(tmp_path))
    assert proc.returncode == 2
    assert "talp_rank*.json" in proc.stderr


def test_cli_merges_spool(tmp_path):
    spool = FileSpoolTransport(str(tmp_path))
    for r in range(2):
        spool.submit(_run_monitor(r).finalize(), rank=r)
    out = tmp_path / "job.json"
    proc = _run_cli(str(tmp_path), "--json-out", str(out))
    assert proc.returncode == 0, proc.stderr
    assert "TALP" in proc.stdout or "region" in proc.stdout
    job = talp_result_from_json(out.read_text())
    assert job["Global"].n_ranks == 2
