"""Beyond-paper extensions: POP scalability branch across runs, and the
ASCII trace renderer (the paper's visual-validation workflow)."""

import pytest

from repro.appsim import node_scan
from repro.core.analysis import analyze_trace
from repro.core.backends import SyntheticTraceBuilder
from repro.core.scalability import render_scalability, scalability_scan
from repro.core.traceview import render_trace
from repro.pils import use_case


def _run(nranks, work, mpi):
    b = SyntheticTraceBuilder(nranks=nranks, ndevices=nranks)
    for r in range(nranks):
        b.rank(r).useful(work).offload_kernel(work * 2)
        if mpi:
            b.rank(r).mpi(mpi)
    return analyze_trace(b.build())


def test_perfect_strong_scaling():
    """Halving work per rank when doubling ranks → GE = 1, CS = 1/PE·GE."""
    runs = [_run(2, 1.0, 0.0), _run(4, 0.5, 0.0), _run(8, 0.25, 0.0)]
    pts = scalability_scan(runs, labels=["2", "4", "8"])
    for p in pts:
        p.validate()
        assert p.global_efficiency == pytest.approx(1.0, abs=1e-6)
    assert pts[2].speedup == pytest.approx(4.0, abs=1e-6)


def test_degraded_scaling_shows_in_global_eff():
    """Growing MPI time at scale degrades Global Efficiency via PE."""
    runs = [_run(2, 1.0, 0.0), _run(4, 0.5, 0.2), _run(8, 0.25, 0.3)]
    pts = scalability_scan(runs, labels=["2", "4", "8"])
    ges = [p.global_efficiency for p in pts]
    assert ges[0] == pytest.approx(1.0)
    assert ges[1] < 1.0 and ges[2] < ges[1]
    for p in pts:
        p.validate()
    text = render_scalability(pts)
    assert "GlobalEff" in text and "8" in text


def test_scalability_on_appsim_scan():
    """XSHELLS node scan: global efficiency decays monotonically."""
    scan = node_scan("xshells")
    pts = scalability_scan(
        [scan[n] for n in (1, 2, 4, 8)],
        labels=["1", "2", "4", "8"],
        resources=[4, 8, 16, 32],
    )
    ges = [p.global_efficiency for p in pts]
    assert all(ges[i] >= ges[i + 1] - 1e-9 for i in range(len(ges) - 1))
    for p in pts:
        p.validate(tol=1e-6)


def test_render_trace_pils():
    """The renderer shows the paper's trace structure: kernels on the
    loaded device, memory segment on device 0 only (use case 6)."""
    tr = use_case("uc6")["trace"]
    art = render_trace(tr, width=60)
    lines = art.splitlines()
    assert len(lines) == 1 + 2 + 2   # header + 2 ranks + 2 devices
    dev0 = next(l for l in lines if l.startswith("dev    0"))
    dev1 = next(l for l in lines if l.startswith("dev    1"))
    assert "=" in dev0      # the large transfer (green in the paper)
    assert "=" not in dev1
    assert "#" in dev0 and "#" in dev1
    rank1 = next(l for l in lines if l.startswith("rank   1"))
    assert "m" in rank1     # rank 1 waits in MPI (red in the paper)


def test_render_trace_idle_classification():
    b = SyntheticTraceBuilder(nranks=1, ndevices=1)
    b.rank(0).useful(1.0).offload_kernel(1.0).useful(2.0)
    art = render_trace(b.build(), width=40)
    dev = next(l for l in art.splitlines() if l.startswith("dev"))
    assert "." in dev and "#" in dev


def test_render_trace_zero_width_window():
    """A degenerate (t0 == t1) window must render instead of dividing by
    ~zero and painting unbounded rows."""
    b = SyntheticTraceBuilder(nranks=1, ndevices=1)
    b.rank(0).useful(1.0).offload_kernel(1.0)
    tr = b.build()
    tr.window = (2.0, 2.0)
    art = render_trace(tr, width=40)
    lines = art.splitlines()
    assert len(lines) == 3
    # nothing painted: host bar blank, device bar all idle
    assert set(lines[1].split("|")[1]) <= {" "}
    assert set(lines[2].split("|")[1]) <= {"."}


def test_render_trace_legend_flag():
    b = SyntheticTraceBuilder(nranks=1, ndevices=1)
    b.rank(0).useful(1.0)
    tr = b.build()
    assert "#=useful" in render_trace(tr).splitlines()[0]
    assert "#=useful" not in render_trace(tr, legend=False).splitlines()[0]
