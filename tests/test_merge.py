"""Multi-rank aggregation (repro.core.merge): merge semantics, transports,
and the job-level metric recomputation the paper's Tables 1–3 rely on."""

import json
import math

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    AllGatherTransport,
    DeviceActivity,
    FileSpoolTransport,
    InProcessGather,
    TalpMonitor,
    merge_results,
    talp_result_from_json,
)
from repro.core.merge import merge_region_results, merge_spool
from repro.core.report import to_json


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_rank_result(rank, useful, offload, mpi, kernel=0.0, memory=0.0,
                     region="step"):
    """One simulated rank: region [0, u+w+m], device records from t=0."""
    clk = FakeClock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    with mon.region(region):
        clk.advance(useful)
        if offload:
            with mon.offload():
                clk.advance(offload)
        if mpi:
            with mon.mpi():
                clk.advance(mpi)
    if kernel:
        mon.add_device_record(0, DeviceActivity.KERNEL, 0.0, kernel)
    if memory:
        mon.add_device_record(0, DeviceActivity.MEMORY, kernel, kernel + memory)
    return mon.finalize()


# ---------------------------------------------------------------------------
# hand-computed 4-rank fixture
# ---------------------------------------------------------------------------
def test_four_rank_fixture_hand_computed():
    """u=[2,1,3,2] w=[1,2,1,1] mpi=[1,1,0,1] → E=4; K=[2,1,2,3]
    M=[1,.5,0,0]. All job-level values below are worked by hand from
    eqs. (6)–(12)."""
    results = [
        make_rank_result(0, 2.0, 1.0, 1.0, kernel=2.0, memory=1.0),
        make_rank_result(1, 1.0, 2.0, 1.0, kernel=1.0, memory=0.5),
        make_rank_result(2, 3.0, 1.0, 0.0, kernel=2.0),
        make_rank_result(3, 2.0, 1.0, 1.0, kernel=3.0),
    ]
    job = merge_results(results, name="job")
    step = job["step"]
    assert step.n_ranks == 4
    assert step.n_devices == 4
    assert step.elapsed == pytest.approx(4.0)

    h = step.host
    assert h.parallel_efficiency == pytest.approx(8.0 / 16.0)        # eq 6
    assert h.mpi_parallel_efficiency == pytest.approx(13.0 / 16.0)   # eq 7
    assert h.device_offload_efficiency == pytest.approx(8.0 / 13.0)  # eq 8
    assert h.load_balance == pytest.approx(13.0 / 16.0)
    assert h.communication_efficiency == pytest.approx(1.0)
    h.validate()

    d = step.device
    assert d.parallel_efficiency == pytest.approx(8.0 / 16.0)        # eq 9
    assert d.load_balance == pytest.approx(8.0 / 12.0)               # eq 10
    assert d.communication_efficiency == pytest.approx(1.0)          # eq 11
    assert d.orchestration_efficiency == pytest.approx(3.0 / 4.0)    # eq 12
    d.validate()

    # PE = LB × CE × OE multiplicativity, explicitly
    assert d.parallel_efficiency == pytest.approx(
        d.load_balance * d.communication_efficiency * d.orchestration_efficiency
    )
    # device-id remap: one device per rank → dense global ids 0..3
    assert sorted(step.device_states) == [0, 1, 2, 3]
    assert step.device_states[3]["kernel"] == pytest.approx(3.0)
    assert step.device_states[0]["idle"] == pytest.approx(1.0)


def test_one_rank_merge_is_identity():
    """A 1-rank merge must reproduce the single-monitor metrics
    bit-for-bit (same floats, not approximately)."""
    res = make_rank_result(0, 1.7, 0.9, 0.3, kernel=1.1, memory=0.4)
    merged = merge_results([res])
    for region in res.regions:
        a, b = res[region], merged[region]
        assert b.elapsed == a.elapsed
        if a.host is None:
            assert b.host is None
        else:
            assert b.host.as_dict() == a.host.as_dict()
        if a.device is None:
            assert b.device is None
        else:
            assert b.device.as_dict() == a.device.as_dict()
        assert b.host_states == a.host_states


# ---------------------------------------------------------------------------
# properties
# ---------------------------------------------------------------------------
durations = st.floats(0.0, 100.0, allow_nan=False, allow_infinity=False)


@st.composite
def rank_params(draw):
    u = draw(durations)
    w = draw(durations)
    m = draw(durations)
    if u + w + m <= 0:
        u = 1.0
    k = draw(st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False)) * (u + w + m)
    mem = draw(st.floats(0.0, 1.0, allow_nan=False, allow_infinity=False)) * max(
        0.0, u + w + m - k
    )
    return (u, w, m, k, mem)


@settings(max_examples=50, deadline=None)
@given(st.lists(rank_params(), min_size=3, max_size=6))
def test_merge_associative(params):
    """merge(merge(a, b), rest) must equal merge(a, b, rest) exactly."""
    results = [
        make_rank_result(r, u, w, m, kernel=k, memory=mem)
        for r, (u, w, m, k, mem) in enumerate(params)
    ]
    left = merge_results(
        [merge_results(results[:2]), merge_results(results[2:])], name="job"
    )
    flat = merge_results(results, name="job")
    assert json.loads(to_json(left)) == json.loads(to_json(flat))


@settings(max_examples=50, deadline=None)
@given(st.lists(rank_params(), min_size=2, max_size=6))
def test_merged_metrics_validate(params):
    """Multiplicativity (PE = LB×CE×OE etc.) must hold on every merge."""
    results = [
        make_rank_result(r, u, w, m, kernel=k, memory=mem)
        for r, (u, w, m, k, mem) in enumerate(params)
    ]
    job = merge_results(results)
    for region in job.regions.values():
        if region.host is not None:
            region.host.validate(tol=1e-7)
            for v in region.host.as_dict().values():
                assert math.isfinite(v)
        if region.device is not None:
            region.device.validate(tol=1e-7)


# ---------------------------------------------------------------------------
# merge semantics details
# ---------------------------------------------------------------------------
def test_region_name_union():
    a = make_rank_result(0, 1.0, 0.5, 0.0, region="solver")
    b = make_rank_result(1, 2.0, 0.0, 0.5, region="io")
    job = merge_results([a, b])
    assert set(job.regions) == {"Global", "solver", "io"}
    # a region measured by one rank has n_ranks=1 in the job report
    assert job["solver"].n_ranks == 1
    assert job["io"].n_ranks == 1
    assert job["Global"].n_ranks == 2


def test_duplicate_rank_rejected():
    a = make_rank_result(0, 1.0, 0.0, 0.0)
    b = make_rank_result(0, 2.0, 0.0, 0.0)
    with pytest.raises(ValueError, match="duplicate rank"):
        merge_results([a, b])


def test_elapsed_is_max_over_ranks():
    a = make_rank_result(0, 1.0, 0.0, 0.0)
    b = make_rank_result(1, 5.0, 0.0, 0.0)
    job = merge_results([a, b])
    assert job["step"].elapsed == pytest.approx(5.0)
    # rank 0's missing 4s show up as lost efficiency, not lost time
    assert job["step"].host.parallel_efficiency == pytest.approx(6.0 / 10.0)


def test_merge_empty_raises():
    with pytest.raises(ValueError):
        merge_results([])
    with pytest.raises(ValueError):
        merge_region_results([])


# ---------------------------------------------------------------------------
# transports
# ---------------------------------------------------------------------------
def _four_ranks():
    return [
        make_rank_result(r, 1.0 + r, 0.5, 0.25, kernel=0.5 + r * 0.3)
        for r in range(4)
    ]


def test_in_process_gather():
    results = _four_ranks()
    g = InProcessGather(world_size=4)
    for r, res in enumerate(results):
        g.submit(res, rank=r)
        assert g.ready() == (r == 3)
    job = g.merge(name="job")
    assert json.loads(to_json(job)) == json.loads(
        to_json(merge_results(results, name="job"))
    )
    with pytest.raises(ValueError):
        g.submit(results[0], rank=0)


def test_file_spool_roundtrip(tmp_path):
    results = _four_ranks()
    spool = FileSpoolTransport(str(tmp_path), world_size=4)
    assert not spool.ready()
    for r, res in enumerate(results):
        spool.submit(res, rank=r)
    assert spool.ready()
    assert spool.spooled_ranks() == [0, 1, 2, 3]
    job = spool.merge(name="job")
    ref = merge_results(results, name="job")
    assert json.loads(to_json(job)) == json.loads(to_json(ref))
    # one-shot helper
    job2 = merge_spool(str(tmp_path), name="job")
    assert json.loads(to_json(job2)) == json.loads(to_json(ref))
    job2["step"].host.validate()
    job2["step"].device.validate()


def test_json_reconstruction_recomputes_metrics():
    """Corrupt serialized metrics must not survive reconstruction: metrics
    are recomputed from the state durations."""
    res = make_rank_result(0, 2.0, 1.0, 1.0, kernel=1.5)
    payload = json.loads(to_json(res))
    payload["regions"]["step"]["host_metrics"]["parallel_efficiency"] = 123.0
    rebuilt = talp_result_from_json(json.dumps(payload))
    assert rebuilt["step"].host.parallel_efficiency == pytest.approx(0.5)
    rebuilt["step"].host.validate()


def test_file_spool_rejects_stale_larger_job(tmp_path):
    """Leftover rank files from a previous, larger job must not silently
    merge into a new smaller job's report."""
    old = _four_ranks()
    spool8 = FileSpoolTransport(str(tmp_path), world_size=8)
    for r, res in enumerate(old):
        spool8.submit(res, rank=r + 4)  # ranks 4..7 of the old 8-rank job
    spool4 = FileSpoolTransport(str(tmp_path), world_size=4)
    spool4.submit(make_rank_result(0, 1.0, 0.0, 0.0), rank=0)
    with pytest.raises(ValueError, match="stale"):
        spool4.ready()
    with pytest.raises(ValueError, match="stale"):
        spool4.merge()


def test_emit_job_report(tmp_path):
    """Launcher helper: None until all ranks spooled, then an atomic
    talp_job.json plus the merged result on the completing rank."""
    from repro.core.merge import emit_job_report

    results = _four_ranks()
    for r in range(3):
        assert emit_job_report(results[r], str(tmp_path), r, 4,
                               verbose=False) is None
        assert not (tmp_path / "talp_job.json").exists()
    job = emit_job_report(results[3], str(tmp_path), 3, 4, verbose=False)
    assert job is not None
    on_disk = json.loads((tmp_path / "talp_job.json").read_text())
    assert on_disk == json.loads(to_json(job))
    # no leftover tmp files from the atomic publish
    assert not [p for p in tmp_path.iterdir() if ".tmp" in p.name]


def test_allgather_transport_single_process_fallback():
    """Without an initialized jax.distributed fleet the allgather
    transport degenerates to a local merge."""
    res = make_rank_result(0, 1.0, 0.5, 0.0, kernel=0.4)
    job = AllGatherTransport().gather(res, name="job")
    assert json.loads(to_json(job)) == json.loads(
        to_json(merge_results([res], name="job"))
    )


def test_allgather_gather_sample_single_process_fallback():
    """gather_sample degenerates to a local merge_samples on one process,
    and the mid-run snapshot algebra agrees with the finalized merge."""
    from repro.core.merge import merge_samples

    clk = FakeClock()
    mon = TalpMonitor("rank0", clock=clk)
    mon.open_region("step")
    clk.advance(1.0)
    with mon.offload():
        clk.advance(0.5)
    mon.add_device_record(0, DeviceActivity.KERNEL, 1.0, 1.4)
    # mid-run: region still open when the snapshot is taken
    snap = mon.sample_result()
    job_snap = AllGatherTransport().gather_sample(snap, name="job")
    assert json.loads(to_json(job_snap)) == json.loads(
        to_json(merge_samples([snap], name="job"))
    )
    for rr in job_snap.regions.values():
        rr.host.validate()
        if rr.device is not None:
            rr.device.validate()
    # nothing happens after the snapshot, so the finalized merge agrees
    mon.close_region("step")
    final = merge_results([mon.finalize()], name="job")
    g_snap = job_snap["step"]
    g_final = final["step"]
    assert g_snap.elapsed == pytest.approx(g_final.elapsed)
    assert g_snap.host.parallel_efficiency == pytest.approx(
        g_final.host.parallel_efficiency)
    assert g_snap.device.parallel_efficiency == pytest.approx(
        g_final.device.parallel_efficiency)


# ---------------------------------------------------------------------------
# computational-efficiency carry through merge + JSON
# ---------------------------------------------------------------------------
def _ce_rank_result(rank, kernel, model_flops=1e12, peak=100e12):
    """One rank with a flop model attached: CE = launches*model_flops /
    (peak * busy), one launch of ``kernel`` seconds here."""
    from repro.core.backends.analytical import HardwareSpec, StepModel

    clk = FakeClock()
    fm = StepModel(flops=0.0, hbm_bytes=0.0, collective_bytes=0.0,
                   model_flops=model_flops,
                   hw=HardwareSpec(name="t", peak_flops=peak))
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk, flop_model=fm)
    with mon.region("step"):
        mon.add_device_record(0, DeviceActivity.KERNEL, 0.0, kernel)
        clk.advance(1.0)
    return mon.finalize()


def test_merged_computational_efficiency_is_busy_weighted():
    """Job-level CE is the kernel-busy-weighted mean of per-rank CE —
    total useful FLOPs over total busy-time throughput — not the plain
    mean of the per-rank ratios."""
    r0 = _ce_rank_result(0, kernel=0.4)   # CE = 1e12/(100e12*0.4) = 0.025
    r1 = _ce_rank_result(1, kernel=0.1)   # CE = 0.1
    ce0 = r0["step"].device.computational_efficiency
    ce1 = r1["step"].device.computational_efficiency
    assert ce0 == pytest.approx(0.025)
    assert ce1 == pytest.approx(0.1)
    job = merge_results([r0, r1], name="job")
    merged = job["step"].device.computational_efficiency
    assert merged == pytest.approx((ce0 * 0.4 + ce1 * 0.1) / 0.5)   # 0.04
    assert merged != pytest.approx((ce0 + ce1) / 2.0)               # 0.0625
    job["step"].device.validate()


def test_computational_efficiency_json_round_trip():
    """CE is a measurement (not derivable from the reduced states), so
    the JSON path must trust it from the payload — and a merge of
    round-tripped payloads must equal the direct merge."""
    r0 = _ce_rank_result(0, kernel=0.4)
    r1 = _ce_rank_result(1, kernel=0.1)
    back0 = talp_result_from_json(to_json(r0))
    assert back0["step"].device.computational_efficiency == pytest.approx(
        r0["step"].device.computational_efficiency)
    via_json = merge_results(
        [talp_result_from_json(to_json(r)) for r in (r0, r1)], name="job")
    direct = merge_results([r0, r1], name="job")
    assert via_json["step"].device.computational_efficiency == pytest.approx(
        direct["step"].device.computational_efficiency)


def test_merge_without_flop_model_has_no_ce():
    job = merge_results(
        [make_rank_result(0, 1.0, 0.5, 0.0, kernel=0.4)], name="job")
    assert job["step"].device.computational_efficiency is None
