"""Substrate tests: data pipeline, optimizer, checkpointing (sync+async,
rotation, resume), failure-injection restart, straggler detection."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import (
    latest_step,
    list_steps,
    restore_checkpoint,
    save_checkpoint,
)
from repro.checkpoint.manager import CheckpointManager
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, SyntheticTokenPipeline
from repro.launch.steps import init_train_state, make_train_step, train_state_shapes
from repro.launch.train import train
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state
from repro.runtime.fault_tolerance import (
    Heartbeat,
    StragglerDetector,
    run_with_restarts,
)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_pipeline_deterministic_across_restarts():
    cfg = DataConfig(global_batch=4, seq_len=16, vocab_size=100, seed=7)
    p1 = SyntheticTokenPipeline(cfg, process_index=0, process_count=1)
    p2 = SyntheticTokenPipeline(cfg, process_index=0, process_count=1)
    for step in (0, 3, 11):
        b1, b2 = p1.batch_at(step), p2.batch_at(step)
        np.testing.assert_array_equal(b1["labels"], b2["labels"])
    # different steps and ranks differ
    assert not np.array_equal(p1.batch_at(0)["labels"], p1.batch_at(1)["labels"])
    p3 = SyntheticTokenPipeline(cfg, process_index=1, process_count=2)
    assert not np.array_equal(
        p1.batch_at(0)["labels"][:2], p3.batch_at(0)["labels"]
    )


def test_pipeline_prefetch_thread():
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=50, prefetch=2)
    p = SyntheticTokenPipeline(cfg, process_index=0, process_count=1)
    it = iter(p)
    batches = [next(it) for _ in range(3)]
    p.stop()
    for i, b in enumerate(batches):
        np.testing.assert_array_equal(b["labels"], p.batch_at(i)["labels"])


def test_pipeline_embed_frontend():
    cfg = DataConfig(global_batch=2, seq_len=8, vocab_size=50, embed_dim=16)
    p = SyntheticTokenPipeline(cfg, process_index=0, process_count=1)
    b = p.batch_at(0)
    assert b["inputs"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    params = {"w": jnp.array([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, metrics = adamw_update(cfg, params, g, opt)
    assert float(loss(params)) < 0.1
    assert float(metrics["grad_norm"]) >= 0


def test_adamw_grad_compression_path():
    cfg = AdamWConfig(lr=0.01, grad_dtype="bfloat16")
    params = {"w": jnp.ones((8,), jnp.float32)}
    opt = init_opt_state(params)
    g = {"w": jnp.full((8,), 0.123456789, jnp.float32)}
    p2, _, _ = adamw_update(cfg, params, g, opt)
    assert np.all(np.isfinite(np.asarray(p2["w"])))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------
def _tiny_state():
    return {
        "params": {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                   "nested": {"b": jnp.ones((4,), jnp.bfloat16)}},
        "step": jnp.int32(5),
    }


def test_checkpoint_roundtrip(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), 5, state)
    target = jax.eval_shape(lambda: _tiny_state())
    out = restore_checkpoint(str(tmp_path), 5, target)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)),
        state, out,
    )


def test_checkpoint_atomicity_no_partial(tmp_path):
    state = _tiny_state()
    save_checkpoint(str(tmp_path), 1, state)
    # a stale .tmp dir from a crashed writer must be invisible
    os.makedirs(tmp_path / "step_9.tmp", exist_ok=True)
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 0, {"w": jnp.ones((2, 2))})
    bad_target = {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32)}
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 0, bad_target)


def test_manager_rotation_and_async(tmp_path):
    m = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    for s in range(5):
        m.save(s, {"x": jnp.full((3,), s, jnp.float32)})
    m.wait()
    assert list_steps(str(tmp_path)) == [3, 4]
    restored, nxt = m.restore_latest({"x": jax.ShapeDtypeStruct((3,), jnp.float32)})
    assert nxt == 5
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.full(3, 4.0))


def test_manager_restore_empty(tmp_path):
    m = CheckpointManager(str(tmp_path))
    state, nxt = m.restore_latest({"x": jax.ShapeDtypeStruct((1,), jnp.float32)})
    assert state is None and nxt == 0


# ---------------------------------------------------------------------------
# train resume: bitwise state equality (restart == uninterrupted)
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_train_resume_bitwise(tmp_path):
    cfg = smoke_config("llama3.2-3b")
    kw = dict(steps=6, global_batch=2, seq_len=32, verbose=False,
              opt_cfg=AdamWConfig(warmup_steps=2, total_steps=6))
    # uninterrupted run
    s_full, h_full, _ = train(cfg, ckpt_dir=None, **kw)
    # interrupted at step 3 (checkpoint every 3), then resumed
    ck = str(tmp_path / "ck")
    with pytest.raises(RuntimeError):
        train(cfg, ckpt_dir=ck, ckpt_every=3, fail_at_step=4, **kw)
    s_res, h_res, _ = train(cfg, ckpt_dir=ck, ckpt_every=3, **kw)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b)
        ),
        s_full["params"], s_res["params"],
    )
    assert h_res[-1]["step"] == h_full[-1]["step"]


def test_run_with_restarts_counts():
    calls = []

    def run_fn(attempt):
        calls.append(attempt)
        if attempt < 2:
            raise RuntimeError("boom")
        return 10

    report = run_with_restarts(run_fn, max_restarts=5)
    assert report.restarts == 2
    assert calls == [0, 1, 2]


def test_run_with_restarts_exhausts():
    def run_fn(attempt):
        raise RuntimeError("always")

    with pytest.raises(RuntimeError):
        run_with_restarts(run_fn, max_restarts=2)


# ---------------------------------------------------------------------------
# straggler / heartbeat
# ---------------------------------------------------------------------------
def test_straggler_detector():
    d = StragglerDetector(window=10, factor=2.0)
    for s in range(10):
        assert not d.observe(s, 1.0)
    assert d.observe(10, 5.0)       # 5x median
    assert not d.observe(11, 1.1)
    assert d.events == [10]


def test_heartbeat():
    t = {"now": 0.0}
    hb = Heartbeat(clock=lambda: t["now"])
    assert not hb.alive(deadline=1.0)
    hb.beat()
    t["now"] = 0.5
    assert hb.alive(1.0)
    t["now"] = 2.0
    assert not hb.alive(1.0)
    assert hb.count == 1
