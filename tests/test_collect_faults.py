"""Fault-tolerant collection (repro.core.collect + the tolerant merge
paths in repro.core.merge): quarantine of corrupt spool payloads,
partial-rank coverage accounting, straggler deadlines, atomic spool
publication, and the deterministic FaultPlan injection layer."""

import io
import json
import os
import tempfile
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import DeviceActivity
from repro.core.collect import (
    FaultPlan,
    QuarantinedSpool,
    RankCoverage,
    SpoolPayloadError,
    read_spool_payload,
    wait_for_ranks,
)
from repro.core.merge import (
    SPOOL_BINARY_VERSION,
    FileSpoolTransport,
    emit_job_report,
    load_spool_payload,
    merge_results,
    merge_spool,
    result_to_spool_bytes,
    talp_result_from_json,
)
from repro.core.report import render_tables, to_json
from repro.core.talp import TalpMonitor


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def make_rank_result(rank, useful=1.0, offload=0.5, mpi=0.25, kernel=0.4):
    clk = FakeClock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    with mon.region("step"):
        clk.advance(useful)
        if offload:
            with mon.offload():
                clk.advance(offload)
        if mpi:
            with mon.mpi():
                clk.advance(mpi)
    if kernel:
        mon.add_device_record(0, DeviceActivity.KERNEL, 0.0, kernel)
    return mon.finalize()


def fill_spool(tmp_path, n_ranks, **kw):
    sp = FileSpoolTransport(str(tmp_path))
    for r in range(n_ranks):
        sp.submit(make_rank_result(r, useful=1.0 + r, **kw), rank=r)
    return sp


# ---------------------------------------------------------------------------
# corrupted-spool corpus: every corruption class quarantined with a reason
# ---------------------------------------------------------------------------
def _future_version_blob(result):
    blob = result_to_spool_bytes(result)
    with np.load(io.BytesIO(blob)) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode("utf-8"))
        arrays = {k: z[k] for k in z.files if k != "header"}
    header["version"] = SPOOL_BINARY_VERSION + 98
    buf = io.BytesIO()
    np.savez(buf, header=np.frombuffer(json.dumps(header).encode("utf-8"),
                                       dtype=np.uint8), **arrays)
    return buf.getvalue()


def _mangled_header_blob(result):
    """Valid NPZ container, unparseable JSON header member."""
    buf = io.BytesIO()
    np.savez(buf, header=np.frombuffer(b"{definitely not json",
                                       dtype=np.uint8))
    return buf.getvalue()


def test_corrupt_spool_corpus_quarantined(tmp_path):
    """Truncated NPZ, zero-byte file, future SPOOL_BINARY_VERSION,
    mangled JSON header (binary) and mangled legacy JSON text are each
    quarantined with a reason string; the surviving ranks still merge."""
    sp = fill_spool(tmp_path, 6)

    p0 = tmp_path / "talp_rank00000.npz"       # truncated mid-file
    os.truncate(p0, p0.stat().st_size // 2)
    (tmp_path / "talp_rank00001.npz").write_bytes(b"")   # zero-byte
    (tmp_path / "talp_rank00002.npz").write_bytes(       # future version
        _future_version_blob(make_rank_result(2)))
    (tmp_path / "talp_rank00003.npz").write_bytes(       # mangled header
        _mangled_header_blob(make_rank_result(3)))
    os.unlink(tmp_path / "talp_rank00004.npz")           # legacy JSON,
    (tmp_path / "talp_rank00004.json").write_text("{oops")  # mangled

    job = sp.merge(name="job", allow_missing=True, expected=6)
    cov = job.rank_coverage
    assert cov.merged == [5]
    assert cov.missing == []
    assert sorted(q.rank for q in cov.quarantined) == [0, 1, 2, 3, 4]
    reasons = {q.rank: q.reason for q in cov.quarantined}
    assert "truncated" in reasons[0]
    assert "zero-byte" in reasons[1]
    assert "version" in reasons[2]
    assert "mangled" in reasons[3] or "malformed" in reasons[3]
    assert "JSON" in reasons[4] or "json" in reasons[4]
    # every quarantined payload was moved aside with a reason sidecar
    qdir = tmp_path / "quarantine"
    for q in cov.quarantined:
        moved = qdir / os.path.basename(q.path)
        assert moved.exists()
        sidecar = json.loads((str(moved) + ".reason.json")
                             and open(str(moved) + ".reason.json").read())
        assert sidecar["reason"] == q.reason
    # the spool directory re-merges cleanly now (one rank left)
    again = merge_spool(str(tmp_path), allow_missing=True, expected=6)
    assert again.rank_coverage.merged == [5]
    # survivor's metrics identical to a clean single-rank merge
    clean = merge_results([make_rank_result(5, useful=6.0)], name="job")
    assert (json.loads(to_json(job))["regions"]
            == json.loads(to_json(clean))["regions"])


def test_strict_merge_still_raises_on_corruption(tmp_path):
    """Default (non-tolerant) behaviour is unchanged: a corrupt payload
    fails the merge loudly."""
    sp = fill_spool(tmp_path, 2)
    os.truncate(tmp_path / "talp_rank00000.npz", 10)
    with pytest.raises(Exception):
        sp.merge()


def test_read_spool_payload_reason_classes(tmp_path):
    p = tmp_path / "talp_rank00000.npz"
    p.write_bytes(b"")
    with pytest.raises(SpoolPayloadError, match="zero-byte"):
        read_spool_payload(str(p))
    with pytest.raises(SpoolPayloadError, match="unreadable"):
        read_spool_payload(str(tmp_path / "nonexistent.npz"))
    p.write_bytes(b"PK\x03\x04 definitely truncated")
    with pytest.raises(SpoolPayloadError):
        read_spool_payload(str(p))
    j = tmp_path / "talp_rank00001.json"
    j.write_text("not json at all")
    with pytest.raises(SpoolPayloadError, match="JSON"):
        read_spool_payload(str(j))


def test_tolerant_merge_quarantines_stale_ranks(tmp_path):
    """Rank ids outside [0, world) are quarantined as stale instead of
    raising like the strict path."""
    sp = fill_spool(tmp_path, 2)
    sp.submit(make_rank_result(7), rank=7)   # leftover from a bigger job
    job = sp.merge(name="job", allow_missing=True, expected=2)
    cov = job.rank_coverage
    assert cov.merged == [0, 1] and cov.missing == []
    assert [q.rank for q in cov.quarantined] == [7]
    assert "stale" in cov.quarantined[0].reason


# ---------------------------------------------------------------------------
# hypothesis: any non-empty subset of a rank set merges and validates,
# and the coverage annotation exactly names the missing ranks
# ---------------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(
    n_ranks=st.integers(min_value=1, max_value=6),
    drop_mask=st.lists(st.booleans(), min_size=6, max_size=6),
    useful=st.lists(
        st.floats(min_value=0.01, max_value=10.0,
                  allow_nan=False, allow_infinity=False),
        min_size=6, max_size=6,
    ),
)
def test_any_surviving_subset_merges_and_validates(
    n_ranks, drop_mask, useful
):
    with tempfile.TemporaryDirectory() as tmp:
        _check_subset_merge(tmp, n_ranks, drop_mask, useful)


def _check_subset_merge(tmp, n_ranks, drop_mask, useful):
    sp = FileSpoolTransport(tmp)
    for r in range(n_ranks):
        sp.submit(make_rank_result(r, useful=useful[r]), rank=r)
    dropped = sorted(r for r in range(n_ranks) if drop_mask[r])
    survivors = [r for r in range(n_ranks) if r not in dropped]
    for r in dropped:
        os.unlink(os.path.join(tmp, f"talp_rank{r:05d}.npz"))
    if not survivors:
        with pytest.raises(ValueError):
            sp.merge(allow_missing=True, expected=n_ranks)
        return
    job = sp.merge(name="job", allow_missing=True, expected=n_ranks)
    cov = job.rank_coverage
    assert cov.expected == n_ranks
    assert cov.merged == survivors
    assert cov.missing == dropped           # exactly the missing ranks
    assert not cov.quarantined
    for rr in job.regions.values():
        if rr.host is not None:
            rr.host.validate()
        if rr.device is not None:
            rr.device.validate()
    # the partial merge equals the clean merge of the survivors
    clean = merge_results(
        [make_rank_result(r, useful=useful[r]) for r in survivors],
        name="job",
    )
    assert (json.loads(to_json(job))["regions"]
            == json.loads(to_json(clean))["regions"])


# ---------------------------------------------------------------------------
# atomic publication: readers interleaved with writers never see partials
# ---------------------------------------------------------------------------
def test_submit_atomic_under_interleaved_reader(tmp_path):
    """Regression for torn spool writes: a reader polling the published
    path while two writer threads repeatedly submit the same rank must
    only ever observe complete, parseable payloads."""
    sp = FileSpoolTransport(str(tmp_path))
    path = os.path.join(str(tmp_path), "talp_rank00000.npz")
    results = [make_rank_result(0, useful=1.0 + i * 0.5) for i in range(2)]
    stop = threading.Event()
    errors = []
    seen = [0]

    def reader():
        while not stop.is_set():
            if os.path.exists(path):
                try:
                    load_spool_payload(path)
                    seen[0] += 1
                except Exception as e:  # torn read — the regression
                    errors.append(repr(e))
                    return

    def writer(res):
        for _ in range(40):
            sp.submit(res, rank=0)

    t_read = threading.Thread(target=reader)
    t_read.start()
    writers = [threading.Thread(target=writer, args=(r,)) for r in results]
    for t in writers:
        t.start()
    for t in writers:
        t.join()
    stop.set()
    t_read.join()
    assert not errors, f"reader observed a torn payload: {errors[0]}"
    assert seen[0] > 0, "reader never observed the payload at all"
    # no temp-file litter, and the final payload is complete
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    load_spool_payload(path)


def test_submit_steps_atomic_tmp_cleanup(tmp_path):
    """submit_steps shares the unique-tmp + fsync + replace publication."""
    from repro.core.telemetry.stepseries import StepSeriesRecorder

    clk = FakeClock()
    mon = TalpMonitor("r0", clock=clk)
    rec = StepSeriesRecorder(mon, capacity=8, regions=("step",))
    with mon.region("step"):
        clk.advance(1.0)
    rec.close()
    sp = FileSpoolTransport(str(tmp_path))
    sp.submit_steps(rec.series, rank=0)
    assert not [n for n in os.listdir(tmp_path) if n.endswith(".tmp")]
    assert 0 in sp.collect_steps()


# ---------------------------------------------------------------------------
# straggler deadline with backoff
# ---------------------------------------------------------------------------
def test_wait_for_ranks_deadline_and_backoff():
    """The poll interval backs off exponentially (capped), the deadline
    is honoured, and arrival short-circuits the wait."""
    now = [0.0]
    sleeps = []
    ranks = []

    def clock():
        return now[0]

    def sleep(dt):
        sleeps.append(dt)
        now[0] += dt

    # never arrives: runs to the deadline with backing-off polls
    got = wait_for_ranks(lambda: list(ranks), world_size=2, max_wait=3.0,
                         poll=0.1, backoff=2.0, max_poll=1.0,
                         clock=clock, sleep=sleep)
    assert got == []
    assert now[0] <= 3.0 + 1e-9
    assert sleeps[0] == pytest.approx(0.1)
    assert sleeps[1] == pytest.approx(0.2)
    assert max(sleeps) <= 1.0 + 1e-9        # capped backoff

    # arrival stops the wait early
    now[0] = 0.0
    sleeps.clear()

    def sleep_and_arrive(dt):
        sleeps.append(dt)
        now[0] += dt
        if len(sleeps) == 2:
            ranks.extend([0, 1])

    got = wait_for_ranks(lambda: list(ranks), world_size=2, max_wait=60.0,
                         poll=0.1, clock=clock, sleep=sleep_and_arrive)
    assert got == [0, 1]
    assert len(sleeps) == 2
    assert now[0] < 1.0


def test_transport_wait_for_ranks(tmp_path):
    """FileSpoolTransport.wait_for_ranks returns stragglers that land
    mid-wait (a second thread playing the late rank)."""
    sp = FileSpoolTransport(str(tmp_path), world_size=2)
    sp.submit(make_rank_result(0), rank=0)

    def late_rank():
        sp.submit(make_rank_result(1), rank=1)

    t = threading.Timer(0.15, late_rank)
    t.start()
    try:
        got = sp.wait_for_ranks(max_wait=10.0)
    finally:
        t.join()
    assert got == [0, 1]
    # a deadline of zero returns immediately with whatever is present
    assert sp.wait_for_ranks(max_wait=0.0) == [0, 1]


# ---------------------------------------------------------------------------
# RankCoverage semantics
# ---------------------------------------------------------------------------
def test_rank_coverage_inference_and_round_trip():
    q = QuarantinedSpool(path="talp_rank00003.npz", reason="zero-byte file",
                         rank=3)
    cov = RankCoverage.compute(merged=[0, 2], quarantined=[q])
    assert cov.expected == 4                 # inferred: max observed id + 1
    assert cov.merged == [0, 2]
    assert cov.missing == [1]
    assert not cov.complete
    back = RankCoverage.from_dict(json.loads(json.dumps(cov.as_dict())))
    assert back.as_dict() == cov.as_dict()
    assert "3/" not in cov.summary() and cov.summary() == "2/4 rank(s) merged"

    full = RankCoverage.compute(merged=[0, 1], expected=2)
    assert full.complete
    assert "all expected ranks merged" in full.render_text()


def test_coverage_through_report_exporter_and_trace():
    """The rank_coverage annotation survives the JSON round trip and
    surfaces in the text report, the telemetry JSONL record and the
    Chrome trace metadata."""
    from repro.core.telemetry.exporter import TelemetryExporter
    from repro.core.telemetry.traceexport import (
        export_result, validate_chrome_trace,
    )

    cov = RankCoverage.compute(
        merged=[0], expected=3,
        quarantined=[QuarantinedSpool(path="talp_rank00001.npz",
                                      reason="zero-byte file", rank=1)],
    )
    job = merge_results([make_rank_result(0)], name="job", coverage=cov)

    # JSON round trip
    back = talp_result_from_json(to_json(job))
    assert back.rank_coverage.as_dict() == cov.as_dict()
    # text report block
    txt = render_tables(job)
    assert "rank coverage: 1/3 rank(s) merged" in txt
    assert "missing rank(s)    : 2" in txt
    assert "zero-byte file" in txt
    # Chrome trace metadata (and the trace still validates structurally)
    trace = export_result(job)
    validate_chrome_trace(trace)
    other = json.loads(trace)["otherData"]
    assert other["rank_coverage"] == cov.as_dict()
    # telemetry JSONL record
    clk = FakeClock()
    mon = TalpMonitor("job", clock=clk)
    exp = TelemetryExporter(mon)
    snap = exp.sample()
    snap.result.rank_coverage = cov
    rec = exp.jsonl_record(snap)
    assert rec["rank_coverage"] == cov.as_dict()
    exp.close()


def test_merge_without_losses_has_no_coverage_by_default(tmp_path):
    """Strict merges stay byte-identical to the pre-fault-tolerance
    output: no rank_coverage key appears."""
    sp = fill_spool(tmp_path, 2)
    job = sp.merge(name="job")
    assert job.rank_coverage is None
    assert "rank_coverage" not in json.loads(to_json(job))
    # tolerant merge of a complete spool annotates complete coverage
    job2 = sp.merge(name="job", allow_missing=True, expected=2)
    assert job2.rank_coverage.complete
    assert (json.loads(to_json(job2))["regions"]
            == json.loads(to_json(job))["regions"])


# ---------------------------------------------------------------------------
# FaultPlan
# ---------------------------------------------------------------------------
def test_fault_plan_parsing_forms(tmp_path):
    spec = {"drop": [2], "truncate": {"1": 96},
            "corrupt": {"0": {"offset": 4, "length": 2, "xor": 255}},
            "delay": {"1": 0.25}, "clock_skew": {"0": 1.5}}
    from_dict = FaultPlan.from_spec(spec)
    from_json_str = FaultPlan.from_spec(json.dumps(spec))
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(spec))
    from_file = FaultPlan.from_spec(str(path))
    from_at_file = FaultPlan.from_spec("@" + str(path))
    for fp in (from_dict, from_json_str, from_file, from_at_file):
        assert fp.drops(2) and not fp.drops(0)
        assert fp.truncate == {1: 96}
        assert fp.delay_s(1) == 0.25 and fp.delay_s(0) == 0.0
        assert fp.skew_s(0) == 1.5
        assert fp.touches(0) and not fp.touches(3)
    assert FaultPlan.from_spec(from_dict) is from_dict
    assert "drop submit" in from_dict.describe(2)
    assert from_dict.describe(3) == "no faults"
    with pytest.raises(ValueError, match="unknown fault plan"):
        FaultPlan.from_spec({"explode": True})
    with pytest.raises(ValueError, match="JSON"):
        FaultPlan.from_spec("not a plan and not a file")


def test_fault_plan_mutations(tmp_path):
    fp = FaultPlan.from_spec({
        "drop": [9], "truncate": {"1": 4},
        "corrupt": {"2": {"offset": 1, "length": 2, "xor": 0xFF}},
    })
    assert fp.mutate_bytes(b"abcdefgh", 9) is None
    assert fp.mutate_bytes(b"abcdefgh", 1) == b"abcd"
    assert fp.mutate_bytes(b"abcdefgh", 0) == b"abcdefgh"
    corrupted = fp.mutate_bytes(b"abcdefgh", 2)
    assert corrupted[0:1] == b"a" and corrupted[3:] == b"defgh"
    assert corrupted[1] == (ord("b") ^ 0xFF)

    p = tmp_path / "payload.bin"
    p.write_bytes(b"abcdefgh")
    assert "truncated" in fp.apply_to_file(str(p), 1)
    assert p.read_bytes() == b"abcd"
    p.write_bytes(b"abcdefgh")
    assert "corrupted" in fp.apply_to_file(str(p), 2)
    assert p.read_bytes() == fp.mutate_bytes(b"abcdefgh", 2)
    p.write_bytes(b"abcdefgh")
    assert fp.apply_to_file(str(p), 0) is None
    assert p.read_bytes() == b"abcdefgh"


def test_emit_job_report_with_fault_plan(tmp_path):
    """emit_job_report honours drop/corrupt injection and merges the
    survivors tolerantly with coverage (the in-driver analogue of the
    CI fault scenario)."""
    plan = FaultPlan.from_spec({"drop": [2], "truncate": {"1": 64}})
    out = []
    for rank in range(3):
        out.append(emit_job_report(
            make_rank_result(rank), str(tmp_path), rank, world_size=3,
            verbose=False, fault_plan=plan,
        ))
    # rank 2 dropped → the spool never completes → nobody merged
    assert out == [None, None, None]
    assert not (tmp_path / "talp_rank00002.npz").exists()
    assert (tmp_path / "talp_rank00001.npz").stat().st_size == 64

    # a 2-rank world with only a corruption *does* self-merge, tolerantly
    tmp2 = tmp_path / "two"
    plan2 = FaultPlan.from_spec({"truncate": {"0": 64}})
    r0 = emit_job_report(make_rank_result(0), str(tmp2), 0, world_size=2,
                         verbose=False, fault_plan=plan2)
    r1 = emit_job_report(make_rank_result(1), str(tmp2), 1, world_size=2,
                         verbose=False, fault_plan=plan2)
    job = r1 if r1 is not None else r0
    assert job is not None
    cov = job.rank_coverage
    assert cov.merged == [1]
    assert [q.rank for q in cov.quarantined] == [0]
    # the published job artifact carries the annotation too
    disk = json.loads((tmp2 / "talp_job.json").read_text())
    assert disk["rank_coverage"]["merged"] == [1]
