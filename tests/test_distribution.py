"""Distribution tests that need >1 device: run in subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process (and all smoke tests) keep seeing 1 device."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, devices: int = 8, timeout: int = 480):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


@pytest.mark.slow
def test_sharded_train_step_runs_and_matches_single_device():
    """A real sharded train step on a 2x4 mesh produces the same loss as
    the unsharded step (SPMD correctness, not just compile)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import smoke_config
        from repro.launch.steps import init_train_state, make_train_step
        from repro.launch.mesh import make_mesh
        from repro.sharding.partition import state_shardings, batch_pspec
        import repro.launch.steps as steps

        cfg = smoke_config("llama3.2-3b")
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        batch = {
            "inputs": jnp.zeros((8, 64), jnp.int32),
            "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0,
                                         cfg.vocab_size, jnp.int32),
        }
        step = make_train_step(cfg)
        # single-device reference
        ref_state, ref_metrics = jax.jit(step)(state, batch)
        ref_loss = float(ref_metrics["loss"])

        mesh = make_mesh((2, 4), ("data", "model"))
        shapes = jax.eval_shape(lambda s: s, state)
        sh = state_shardings(shapes, mesh, cfg)
        state_sharded = jax.device_put(state, sh)
        bsh = jax.tree.map(
            lambda x: NamedSharding(mesh, batch_pspec(mesh, x.shape[0], x.ndim)),
            batch)
        batch_sharded = jax.device_put(batch, bsh)
        with mesh:
            new_state, metrics = jax.jit(
                step, in_shardings=(sh, bsh))(state_sharded, batch_sharded)
        loss = float(metrics["loss"])
        assert abs(loss - ref_loss) < 1e-3, (loss, ref_loss)
        print("OK", loss, ref_loss)
    """)
    assert "OK" in out


@pytest.mark.slow
def test_elastic_reshard_checkpoint():
    """Save a sharded state on an 8-device mesh, restore onto a 4-device
    mesh (elastic downscale) — values identical."""
    out = _run("""
        import tempfile, jax, jax.numpy as jnp, numpy as np
        from repro.configs import smoke_config
        from repro.launch.steps import init_train_state, train_state_shapes
        from repro.launch.mesh import make_mesh
        from repro.sharding.partition import state_shardings
        from repro.checkpoint.checkpointer import save_checkpoint, restore_checkpoint

        cfg = smoke_config("gemma2-2b")
        state = init_train_state(cfg, jax.random.PRNGKey(0))
        mesh8 = make_mesh((2, 4), ("data", "model"))
        sh8 = state_shardings(jax.eval_shape(lambda s: s, state), mesh8, cfg)
        sharded = jax.device_put(state, sh8)
        d = tempfile.mkdtemp()
        save_checkpoint(d, 0, sharded)

        mesh4 = make_mesh((2, 2), ("data", "model"),
                          devices=jax.devices()[:4])
        shapes = train_state_shapes(cfg)
        sh4 = state_shardings(shapes, mesh4, cfg)
        restored = restore_checkpoint(d, 0, shapes, sh4)
        ref = jax.tree.leaves(state)
        got = jax.tree.leaves(restored)
        for a, b in zip(ref, got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # restored leaves actually live on the 4-device mesh
        leaf = jax.tree.leaves(restored)[0]
        assert len(leaf.sharding.device_set) <= 4
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_decode_step_sharded_runs():
    """serve_step executes (not just compiles) on a 2x2 mesh with sharded
    caches for a hybrid (zamba2) smoke config."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import smoke_config
        from repro.models import lm
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import make_serve_step
        from repro.sharding.partition import (cache_pspec, make_sharding_tree,
                                              param_pspec, batch_pspec)

        cfg = smoke_config("zamba2-2.7b")
        params = lm.init_params(cfg, jax.random.PRNGKey(0))
        caches = lm.init_decode_caches(cfg, 4, 64, filled=True)
        tok = jnp.zeros((4, 1), jnp.int32)
        pos = jnp.full((4,), 64, jnp.int32)
        step = make_serve_step(cfg)
        ref_logits, _, _ = jax.jit(step)(params, tok, pos, caches)

        mesh = make_mesh((2, 2), ("data", "model"))
        psh = make_sharding_tree(params, mesh, cfg, param_pspec)
        csh = make_sharding_tree(caches, mesh, cfg, cache_pspec)
        params_s = jax.device_put(params, psh)
        caches_s = jax.device_put(caches, csh)
        bsh = NamedSharding(mesh, batch_pspec(mesh, 4, 2))
        possh = NamedSharding(mesh, batch_pspec(mesh, 4, 1))
        with mesh:
            logits, _, _ = jax.jit(
                step, in_shardings=(psh, bsh, possh, csh)
            )(params_s, jax.device_put(tok, bsh), jax.device_put(pos, possh),
              caches_s)
        # bf16 params + different reduction order across shards → ~5e-2
        np.testing.assert_allclose(np.asarray(ref_logits, np.float32),
                                   np.asarray(logits, np.float32),
                                   rtol=8e-2, atol=8e-2)
        print("OK")
    """)
    assert "OK" in out


def test_roofline_calibration_semantics():
    """Documents/verifies the two facts the roofline pipeline relies on:
    (1) cost_analysis counts a scan body once; (2) costs are per-device
    after SPMD partitioning."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P

        def costs(compiled):
            # jax returns either a dict or a one-element list of dicts
            # depending on version
            c = compiled.cost_analysis()
            return c[0] if isinstance(c, (list, tuple)) else c

        # large enough that XLA partitions instead of replicating
        x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
        ws = jax.ShapeDtypeStruct((10, 1024, 1024), jnp.float32)
        dot_flops = 2 * 1024**3

        f = lambda a, b: a @ b
        c1 = costs(jax.jit(f).lower(x, w).compile())
        assert abs(c1["flops"] - dot_flops) / dot_flops < 0.05

        def g(a, bs):
            return jax.lax.scan(lambda h, b: (h @ b, None), a, bs)[0]
        c2 = costs(jax.jit(g).lower(x, ws).compile())
        # scan body counted ONCE, not x10:
        assert c2["flops"] < 2 * dot_flops, c2["flops"]

        # 2-D mesh with both operands sharded: partitioning is profitable
        mesh = jax.sharding.Mesh(
            np.array(jax.devices()[:4]).reshape(2, 2), ("a", "b"))
        with mesh:
            c3 = costs(jax.jit(
                f,
                in_shardings=(NamedSharding(mesh, P("a", "b")),
                              NamedSharding(mesh, P("b", None))),
            ).lower(x, w).compile())
        # per-device program: ~1/4 of the flops
        assert c3["flops"] < 0.5 * dot_flops, c3["flops"]
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_dryrun_cell_single_pod_small():
    """One full dry-run cell on a reduced mesh footprint via the module
    CLI (8 devices, overriding the mesh through make_mesh is covered
    elsewhere; here we exercise the real 256-chip path end to end)."""
    out = _run("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.dryrun import run_cell
        res = run_cell("gemma2-2b", "decode_32k", multi_pod=False,
                       verbose=False, calibrate=False)
        assert res["status"] == "ok", res
        assert res["collective_bytes"] >= 0
        print("OK", res["dominant"])
    """, devices=512, timeout=560)
    assert "OK" in out
