"""Multi-process conformance: real ``launch/train.py`` rank fleets
exercising the collection transports end to end (see ``mp_harness``).

These are the tests that close the ROADMAP's "validate on a real
multi-process fleet" open item: N actual OS processes race on one spool
directory, and the job-level report they produce must agree bit-for-bit
with the in-process reference merge of the same per-rank payloads.
"""

import json
import os
import subprocess
import sys

import pytest

from mp_harness import (
    FleetResult,
    fleet_env,
    launch_allgather_fleet,
    launch_fleet,
)

from repro.core.merge import InProcessGather, load_spool_payload
from repro.core.report import to_json


def _assert_fleet_ok(res: FleetResult) -> None:
    assert res.ok, res.report()


@pytest.mark.slow
def test_three_rank_fleet_matches_in_process_merge(tmp_path):
    """3 subprocess ranks running ``launch/train.py --talp-spool`` must
    produce a merged job report bit-identical to an in-process 3-rank
    :class:`InProcessGather` merge of the same spooled payloads."""
    spool = tmp_path / "spool"
    res = launch_fleet(str(spool), n_ranks=3)
    _assert_fleet_ok(res)

    job_path = spool / "talp_job.json"
    assert job_path.exists(), "no rank merged the completed spool"
    fleet_json = job_path.read_text()

    gather = InProcessGather(world_size=3)
    for rank in range(3):
        payload = spool / f"talp_rank{rank:05d}.npz"
        assert payload.exists(), f"rank {rank} left no spool payload"
        gather.submit(load_spool_payload(str(payload))[0], rank=rank)
    assert gather.ready()
    reference_json = to_json(gather.merge(name="train"))

    assert fleet_json == reference_json  # bit-identical, not approx
    job = json.loads(fleet_json)
    g = job["regions"]["Global"]
    assert len(g["host_states"]) == 3
    assert g["host_metrics"]["parallel_efficiency"] > 0


@pytest.mark.slow
def test_fault_injected_fleet_partial_merge(tmp_path):
    """The acceptance scenario against a *real* fleet: rank 2 drops its
    submit, rank 1's payload is truncated mid-file by the fault plan.
    Every rank process still exits 0, and the post-mortem tolerant merge
    CLI reports both losses while reproducing the surviving rank's
    metrics bit-identically to a clean merge of that rank."""
    from repro.core.merge import merge_results

    spool = tmp_path / "spool"
    plan = json.dumps({"drop": [2], "truncate": {"1": 200}})
    res = launch_fleet(
        str(spool), n_ranks=3, extra_args=("--talp-fault-plan", plan)
    )
    _assert_fleet_ok(res)

    # The fleet could not self-merge: rank 2 never submitted.
    assert not (spool / "talp_job.json").exists()
    assert not (spool / "talp_rank00002.npz").exists()

    # Clean reference for the surviving rank, read before the tolerant
    # merge quarantines its corrupt neighbour.
    survivor = load_spool_payload(str(spool / "talp_rank00000.npz"))[0]
    reference = json.loads(to_json(merge_results([survivor], name="job")))

    out = tmp_path / "job.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.merge", str(spool),
         "--name", "job", "--allow-missing-ranks", "--expected-ranks", "3",
         "--json-out", str(out)],
        capture_output=True, text=True, env=fleet_env(),
    )
    assert proc.returncode == 0, proc.stderr
    job = json.loads(out.read_text())
    cov = job["rank_coverage"]
    assert cov["expected"] == 3
    assert cov["merged"] == [0]
    assert cov["missing"] == [2]
    assert [q["rank"] for q in cov["quarantined"]] == [1]
    assert cov["quarantined"][0]["reason"]
    # surviving-rank metrics bit-identical to the clean merge
    assert job["regions"] == reference["regions"]
    # the corrupted payload was moved aside, not deleted
    assert (spool / "quarantine" / "talp_rank00001.npz").exists()


@pytest.mark.slow
@pytest.mark.skipif(
    not os.environ.get("TALP_MP_ALLGATHER"),
    reason="multi-process jax.distributed fleet is opt-in: set "
           "TALP_MP_ALLGATHER=1 (needs a JAX build whose distributed "
           "runtime supports multi-process CPU fleets)",
)
def test_allgather_transport_real_fleet(tmp_path):
    """2 real ``jax.distributed`` processes exchange their results via
    the actual ``process_allgather`` collective; every rank must obtain
    the identical job report, equal to the in-process reference merge."""
    from repro.core.merge import merge_results
    from repro.core.talp import TalpMonitor
    from repro.core import DeviceActivity

    res = launch_allgather_fleet(str(tmp_path), n_ranks=2)
    _assert_fleet_ok(res)

    jobs = [
        (tmp_path / f"job_rank{r}.json").read_text() for r in range(2)
    ]
    assert jobs[0] == jobs[1]  # collective: every rank sees the same job

    # in-process reference with the same deterministic per-rank script
    class Clock:
        def __init__(self):
            self.t = 0.0

        def __call__(self):
            return self.t

        def advance(self, dt):
            self.t += dt

    results = []
    for rank in range(2):
        clk = Clock()
        mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
        with mon.region("step"):
            clk.advance(1.0 + rank)
            with mon.offload():
                clk.advance(0.5)
        mon.add_device_record(0, DeviceActivity.KERNEL, 0.0,
                              0.25 * (rank + 1))
        results.append(mon.finalize())
    assert jobs[0] == to_json(merge_results(results, name="job"))
