"""Paper §5.1 validation: the seven PILS use cases reproduce the reported
metric values (Figs. 4–10)."""

import pytest

from repro.pils import run_use_case


def _a(result, key="trace"):
    return result.analyses[key]


def test_uc1_loaded_gpus_underutilized_cpus():
    """All metrics 100% except Device Offload Eff. (low) and
    Orchestration Eff. (82%)."""
    a = _a(run_use_case("uc1"))
    a.validate()
    h, d = a.host, a.device
    assert h.mpi_parallel_efficiency == pytest.approx(1.0, abs=1e-6)
    assert h.communication_efficiency == pytest.approx(1.0, abs=1e-6)
    assert h.load_balance == pytest.approx(1.0, abs=1e-6)
    assert d.load_balance == pytest.approx(1.0, abs=1e-6)
    assert d.communication_efficiency == pytest.approx(1.0, abs=1e-6)
    # the two exceptions:
    assert d.orchestration_efficiency == pytest.approx(0.82, abs=0.005)
    assert h.device_offload_efficiency < 0.25  # "low": CPUs only offload


def test_uc2_loaded_cpus_underutilized_gpus():
    """Host metrics ~100%, Device Offload Eff. 94%, Device PE 5%."""
    a = _a(run_use_case("uc2"))
    a.validate()
    h, d = a.host, a.device
    assert h.device_offload_efficiency == pytest.approx(0.94, abs=0.005)
    assert h.mpi_parallel_efficiency == pytest.approx(1.0, abs=1e-6)
    assert d.parallel_efficiency == pytest.approx(0.05, abs=0.005)


def test_uc3_imbalanced_gpu_computation():
    """Device LB 55%, Device Offload Eff. 26%; host MPI-level imbalance
    appears even though useful CPU time is balanced (paper's intended
    semantics: offload counts as assigned work)."""
    a = _a(run_use_case("uc3"))
    a.validate()
    h, d = a.host, a.device
    assert d.load_balance == pytest.approx(0.55, abs=0.005)
    assert h.device_offload_efficiency == pytest.approx(0.26, abs=0.005)
    # useful is balanced between ranks...
    st = a.host_states
    assert st[0]["useful"] == pytest.approx(st[1]["useful"], rel=1e-6)
    # ...yet host-level LB is degraded by offload imbalance:
    assert h.load_balance < 0.7
    assert h.mpi_parallel_efficiency < 0.7


def test_uc4_imbalanced_both_cpus_more_loaded():
    """Host LB 55%, device LB 55%, low Orchestration Eff."""
    a = _a(run_use_case("uc4"))
    a.validate()
    h, d = a.host, a.device
    assert h.load_balance == pytest.approx(0.55, abs=0.005)
    assert d.load_balance == pytest.approx(0.55, abs=0.005)
    assert d.orchestration_efficiency == pytest.approx(0.20, abs=0.01)
    assert h.device_offload_efficiency < 0.9  # waiting on GPU part of the time


def test_uc5_imbalanced_cpu_same_global_load():
    """Host LB 70%, Orchestration Eff. 33%, low host PE and device PE."""
    a = _a(run_use_case("uc5"))
    a.validate()
    h, d = a.host, a.device
    assert h.load_balance == pytest.approx(0.70, abs=0.005)
    assert d.orchestration_efficiency == pytest.approx(0.33, abs=0.005)
    assert h.parallel_efficiency < 0.75
    assert d.parallel_efficiency < 0.4
    # same global load CPU vs GPU (within 15%)
    cpu = sum(s["useful"] for s in a.host_states.values())
    gpu = sum(s["kernel"] for s in a.device_states.values())
    assert cpu == pytest.approx(gpu, rel=0.15)


def test_uc6_large_data_movement():
    """Device Comm. Eff. 36%, Orchestration 86%, host LB 72%, very low
    Device Offload Eff. (paper reports 9%; see repro.pils docstring)."""
    a = _a(run_use_case("uc6"))
    a.validate()
    h, d = a.host, a.device
    assert d.communication_efficiency == pytest.approx(0.36, abs=0.005)
    assert d.orchestration_efficiency == pytest.approx(0.86, abs=0.005)
    assert h.load_balance == pytest.approx(0.72, abs=0.01)
    assert h.device_offload_efficiency < 0.25  # "main bottleneck"
    # the transfer shows up as memory state only on device 0
    assert a.device_states[0]["memory"] > 0
    assert a.device_states[1]["memory"] == pytest.approx(0.0, abs=1e-9)


def test_uc7_overlap_comparison():
    """Only Device Offload Eff. and Orchestration Eff. differ between the
    runs; offload improves ~+33% to near-optimal; orchestration ≈50%
    in the overlapped run (CPU load is 2× GPU load)."""
    r = run_use_case("uc7")
    a_no, a_ov = r.analyses["no_overlap"], r.analyses["overlap"]
    a_no.validate(); a_ov.validate()
    # unchanged metrics:
    assert a_no.host.load_balance == pytest.approx(a_ov.host.load_balance, abs=1e-6)
    assert a_no.host.communication_efficiency == pytest.approx(
        a_ov.host.communication_efficiency, abs=1e-6)
    assert a_no.device.load_balance == pytest.approx(
        a_ov.device.load_balance, abs=1e-6)
    assert a_no.device.communication_efficiency == pytest.approx(
        a_ov.device.communication_efficiency, abs=1e-6)
    # offload efficiency: 67% -> ~100% (+33%)
    assert a_no.host.device_offload_efficiency == pytest.approx(2 / 3, abs=0.005)
    assert a_ov.host.device_offload_efficiency == pytest.approx(1.0, abs=0.005)
    # orchestration: 33% -> ~50%
    assert a_no.device.orchestration_efficiency == pytest.approx(1 / 3, abs=0.005)
    assert a_ov.device.orchestration_efficiency == pytest.approx(0.5, abs=0.005)


def test_all_use_cases_multiplicative():
    """Every generated trace satisfies the multiplicative hierarchy."""
    for name in ("uc1", "uc2", "uc3", "uc4", "uc5", "uc6", "uc7"):
        r = run_use_case(name)
        for a in r.analyses.values():
            a.validate(tol=1e-6)
            for tree in a.trees().values():
                tree.validate(tol=1e-6)
