"""Telemetry subsystem: Chrome trace export (vectorized + reference +
structural validator), the streaming TelemetryExporter (ring buffer,
JSONL, Prometheus), and TALP self-overhead accounting."""

import io
import json
import urllib.request

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import intervals as ivx
from repro.core.hierarchy import HOST, MetricSpec, StateDurations
from repro.core.merge import merge_region_results, region_result_from_dict
from repro.core.report import from_json, render_text, to_json
from repro.core.states import DeviceActivity, DeviceTimeline, HostTimeline, Trace
from repro.core.talp import RegionResult, TalpMonitor, TalpResult
from repro.core.telemetry import overhead as ovh
from repro.core.telemetry.exporter import TelemetryExporter, TelemetrySnapshot
from repro.core.telemetry import traceexport as tx


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _mk_trace(kern_iv, mem_iv, useful=0.5, offload=0.3, mpi=0.2):
    """Trace with one host rank and one device built from interval rows."""
    tl = DeviceTimeline(device=0)
    kern_iv = np.asarray(kern_iv, dtype=np.float64).reshape(-1, 2)
    mem_iv = np.asarray(mem_iv, dtype=np.float64).reshape(-1, 2)
    if len(kern_iv):
        tl.ingest_arrays(DeviceActivity.KERNEL,
                         kern_iv[:, 0], kern_iv[:, 1])
    if len(mem_iv):
        tl.ingest_arrays(DeviceActivity.MEMORY,
                         mem_iv[:, 0], mem_iv[:, 1])
    tl.compact()
    ends = [e for _, e in list(kern_iv) + list(mem_iv)] or [1.0]
    elapsed = float(max(ends))
    return Trace(
        name="t",
        hosts={0: HostTimeline(rank=0, useful=useful, offload=offload, mpi=mpi)},
        devices={0: tl},
        window=(0.0, elapsed),
    )


# ---------------------------------------------------------------------------
# vectorized slice generation vs per-event reference
# ---------------------------------------------------------------------------
def test_slice_lines_match_reference():
    iv = [[0.0, 1.5], [2.0, 2.25], [3.0, 7.5]]
    lines = tx.slice_lines("K", "device", 2, 3, iv, t0=0.5)
    parsed = [json.loads(l) for l in lines]
    assert parsed == tx.slice_events_loop("K", "device", 2, 3, iv, t0=0.5)


def test_slice_lines_empty():
    assert tx.slice_lines("K", "device", 2, 0, ivx.EMPTY) == []


def test_ts_quantization_is_nanoseconds():
    [line] = tx.slice_lines("K", "d", 2, 0, [[1.23456789e-3, 2.0]])
    ev = json.loads(line)
    # ts: µs quantized to ns; dur: exact float64 round trip
    assert ev["ts"] == float(tx.quantize_ts_us(1.23456789e-3 * 1e6))
    assert ev["dur"] == (2.0 - 1.23456789e-3) * 1e6


def test_export_trace_matches_reference_and_validates():
    trace = _mk_trace([[0.0, 1.0], [2.0, 3.0]], [[0.5, 2.5]])
    vec, ref = tx.export_trace(trace), tx.export_trace_reference(trace)
    assert json.loads(vec)["traceEvents"] == json.loads(ref)["traceEvents"]
    summary = tx.validate_chrome_trace(vec)
    assert summary["counts"]["X"] > 0 and summary["counts"]["M"] >= 2
    # kernel/memory interleave time-ordered in the device lane
    devs = [e for e in json.loads(vec)["traceEvents"]
            if e.get("ph") == "X" and e["pid"] == tx.PID_DEVICE]
    assert [e["ts"] for e in devs] == sorted(e["ts"] for e in devs)
    names = [e["name"] for e in devs]
    assert "Kernel" in names and "Memory" in names


# ---------------------------------------------------------------------------
# property: lanes ordered + non-overlapping, durations are a bit-exact view
# ---------------------------------------------------------------------------
@st.composite
def device_interval_sets(draw, max_n=25, t_max=50.0):
    n = draw(st.integers(0, max_n))
    rows = []
    for _ in range(n):
        s = draw(st.floats(0, t_max, allow_nan=False, allow_infinity=False))
        d = draw(st.floats(0.001, 5.0, allow_nan=False, allow_infinity=False))
        rows.append((s, s + d))
    return rows


@settings(max_examples=40, deadline=None)
@given(device_interval_sets(), device_interval_sets())
def test_trace_export_lane_properties(kern_rows, mem_rows):
    trace = _mk_trace(kern_rows, mem_rows)
    text = tx.export_trace(trace)
    tx.validate_chrome_trace(text)   # ordering + non-overlap per lane
    events = json.loads(text)["traceEvents"]
    dev = [e for e in events
           if e.get("ph") == "X" and e["pid"] == tx.PID_DEVICE]
    # exported durations are a *view* of the flattened interval arrays:
    # bit-for-bit equal, in file order, per kind — so per-lane duration
    # sums equal the (µs-scaled) StateDurations entries exactly.
    tl = trace.devices[0]
    kern = tl.kind_intervals(DeviceActivity.KERNEL)
    mem = ivx.subtract(tl.kind_intervals(DeviceActivity.MEMORY), kern)
    for name, iv in (("Kernel", kern), ("Memory", mem)):
        got = np.array([e["dur"] for e in dev if e["name"] == name])
        want = (iv[:, 1] - iv[:, 0]) * 1e6 if len(iv) else np.empty(0)
        assert got.tolist() == want.tolist()          # bitwise per slice
        assert np.sum(got) == np.sum(want)            # bitwise lane total
        # unit-convention link back to the seconds-domain state totals
        if len(iv):
            assert np.sum(got) / 1e6 == pytest.approx(
                ivx.total(iv), rel=1e-12)
    # ts quantization: exactly the documented rint(ns)/1e3 value
    for e in dev:
        assert e["ts"] == float(tx.quantize_ts_us(e["ts"]))


# ---------------------------------------------------------------------------
# structural validator
# ---------------------------------------------------------------------------
def _doc(events):
    return json.dumps({"traceEvents": events})


def test_validator_rejects_bad_json():
    with pytest.raises(ValueError, match="not valid JSON"):
        tx.validate_chrome_trace("{nope")


def test_validator_rejects_missing_events():
    with pytest.raises(ValueError, match="traceEvents"):
        tx.validate_chrome_trace("{}")


def test_validator_rejects_unknown_phase():
    with pytest.raises(ValueError, match="unknown phase"):
        tx.validate_chrome_trace(_doc([{"ph": "Z"}]))


def test_validator_rejects_negative_dur():
    ev = {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0, "dur": -1}
    with pytest.raises(ValueError, match="negative dur"):
        tx.validate_chrome_trace(_doc([ev]))


def test_validator_rejects_lane_overlap():
    evs = [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0, "dur": 10},
        {"name": "b", "ph": "X", "pid": 1, "tid": 0, "ts": 5, "dur": 1},
    ]
    with pytest.raises(ValueError, match="overlap"):
        tx.validate_chrome_trace(_doc(evs))


def test_validator_allows_overlap_on_other_lane():
    evs = [
        {"name": "a", "ph": "X", "pid": 1, "tid": 0, "ts": 0, "dur": 10},
        {"name": "b", "ph": "X", "pid": 1, "tid": 1, "ts": 5, "dur": 1},
    ]
    assert tx.validate_chrome_trace(_doc(evs))["counts"]["X"] == 2


def test_validator_rejects_unbalanced_markers():
    evs = [{"name": "r", "ph": "B", "pid": 3, "tid": 0, "ts": 0}]
    with pytest.raises(ValueError, match="unbalanced"):
        tx.validate_chrome_trace(_doc(evs))


def test_validator_rejects_end_before_begin():
    evs = [{"name": "r", "ph": "E", "pid": 3, "tid": 0, "ts": 0}]
    with pytest.raises(ValueError, match="without matching"):
        tx.validate_chrome_trace(_doc(evs))


def test_validator_rejects_non_numeric_counter():
    evs = [{"name": "c", "ph": "C", "pid": 4, "tid": 0, "ts": 0,
            "args": {"pe": "high"}}]
    with pytest.raises(ValueError, match="non-numeric"):
        tx.validate_chrome_trace(_doc(evs))


# ---------------------------------------------------------------------------
# monitor / result / job exporters
# ---------------------------------------------------------------------------
def _monitored_run():
    clk = FakeClock()
    mon = TalpMonitor("run", clock=clk)
    with mon.region("step"):
        clk.advance(0.5)
        with mon.offload():
            clk.advance(1.0)
    mon.ingest_device_arrays(0, DeviceActivity.KERNEL,
                             np.array([0.5]), np.array([1.5]))
    return clk, mon


def test_export_monitor_exact_regions_and_counters():
    clk, mon = _monitored_run()
    exp = TelemetryExporter(mon)
    exp.sample()
    clk.advance(0.25)
    exp.sample()
    result = mon.finalize()
    text = tx.export_monitor(mon, result=result, samples=exp.trace_samples())
    summary = tx.validate_chrome_trace(text)
    assert summary["counts"]["B"] >= 2        # Global + step markers
    assert summary["counts"]["B"] == summary["counts"]["E"]
    assert summary["counts"]["C"] >= 2        # one per sample at least
    assert f"{tx.PID_DEVICE}:0" in summary["lanes"]
    # counter series carry hierarchy spec keys
    counters = [e for e in json.loads(text)["traceEvents"]
                if e.get("ph") == "C"]
    assert any("parallel_efficiency" in e["args"] for e in counters)


def test_export_result_synthetic_device_lanes():
    clk = FakeClock()
    mon = TalpMonitor("r", clock=clk)
    with mon.region("w"):
        with mon.offload():
            clk.advance(1.0)
    mon.ingest_device_arrays(0, DeviceActivity.KERNEL,
                             np.array([0.0]), np.array([0.8]))
    result = mon.finalize()
    text = tx.export_result(result)   # no timelines: proportional lanes
    summary = tx.validate_chrome_trace(text)
    assert f"{tx.PID_DEVICE}:0" in summary["lanes"]
    assert summary["counts"]["B"] == summary["counts"]["E"] >= 1


def test_export_job_dense_device_remap():
    def tl_at(shift):
        tl = DeviceTimeline(device=7)   # local id irrelevant after remap
        tl.ingest_arrays(DeviceActivity.KERNEL,
                         np.array([shift + 0.1]), np.array([shift + 0.9]))
        return tl

    clk = FakeClock()
    mon = TalpMonitor("job", clock=clk)
    with mon.region("w"):
        clk.advance(1.0)
    job = mon.finalize()
    rank_tls = {0: {0: tl_at(100.0), 1: tl_at(100.0)}, 1: {0: tl_at(900.0)}}
    text = tx.export_job(job, rank_tls)
    summary = tx.validate_chrome_trace(text)
    # dense gids 0..2 in (rank, local-id) order; per-rank re-anchoring
    # puts every lane near t=0 regardless of the source clock epoch
    for gid in (0, 1, 2):
        assert f"{tx.PID_DEVICE}:{gid}" in summary["lanes"]
    xs = [e for e in json.loads(text)["traceEvents"] if e.get("ph") == "X"
          and e["pid"] == tx.PID_DEVICE]
    assert max(e["ts"] for e in xs) < 5e6   # µs — nothing at the 900 s epoch


def test_cli_validates_trace(tmp_path, capsys):
    trace = _mk_trace([[0.0, 1.0]], [])
    p = tmp_path / "trace.json"
    p.write_text(tx.export_trace(trace))
    tx.main([str(p), "--validate"])
    out = capsys.readouterr().out
    assert json.loads(out)["valid"] is True


def test_cli_rejects_invalid_trace(tmp_path, capsys):
    p = tmp_path / "bad.json"
    p.write_text(_doc([{"ph": "Z"}]))
    with pytest.raises(SystemExit):
        tx.main([str(p), "--validate"])


# ---------------------------------------------------------------------------
# TelemetryExporter: ring buffer, JSONL, Prometheus
# ---------------------------------------------------------------------------
def test_exporter_ring_capacity_and_jsonl():
    clk, mon = _monitored_run()
    buf = io.StringIO()
    exp = TelemetryExporter(mon, capacity=3, jsonl=buf)
    for _ in range(5):
        clk.advance(0.1)
        exp.sample()
    snaps = exp.snapshots()
    assert len(snaps) == 3                       # bounded ring
    assert [s.seq for s in snaps] == [2, 3, 4]   # oldest evicted
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert len(lines) == 5                       # stream keeps everything
    rec = lines[-1]
    assert rec["seq"] == 4 and rec["name"] == "run"
    g = rec["regions"]["Global"]
    assert "host" in g and "device" in g
    assert "parallel_efficiency" in g["host"]
    exp.close()


def test_exporter_capacity_validation():
    clk, mon = _monitored_run()
    with pytest.raises(ValueError, match="capacity"):
        TelemetryExporter(mon, capacity=0)


def test_exporter_last_snapshot_matches_postmortem():
    clk, mon = _monitored_run()
    exp = TelemetryExporter(mon)
    exp.sample()                  # no clock advance before finalize
    result = mon.finalize()
    snap = exp.last
    g_live = snap.result.regions[TalpMonitor.GLOBAL]
    g_post = result.regions[TalpMonitor.GLOBAL]
    assert g_live.elapsed == pytest.approx(g_post.elapsed)
    assert g_live.host.parallel_efficiency == pytest.approx(
        g_post.host.parallel_efficiency)


def test_exporter_prometheus_text_and_http():
    clk, mon = _monitored_run()
    exp = TelemetryExporter(mon)
    assert exp.prometheus_text().startswith("#")   # empty exposition
    exp.sample()
    text = exp.prometheus_text()
    assert "# TYPE talp_host_parallel_efficiency gauge" in text
    assert 'region="Global"' in text and 'trace="run"' in text
    assert "talp_sample_seq" in text
    port = exp.serve(port=0)
    try:
        with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=5
        ) as resp:
            assert resp.status == 200
            assert resp.headers["Content-Type"].startswith("text/plain")
            body = resp.read().decode()
        assert "talp_host_parallel_efficiency" in body
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(
                f"http://127.0.0.1:{port}/nope", timeout=5)
    finally:
        exp.close()
    assert exp._http is None
    exp.close()   # idempotent


def _snapshot_with_hierarchy(hier):
    sd = StateDurations.from_states(
        host_states={0: {"useful": 0.6, "offload": 0.3, "mpi": 0.1}},
        elapsed=1.0,
    )
    frame = hier.compute(sd)
    rr = RegionResult(
        name="Global", elapsed=1.0, n_ranks=1, n_devices=0,
        host=frame, device=None,
        host_states={0: {"useful": 0.6, "offload": 0.3, "mpi": 0.1}},
        device_states={},
    )
    res = TalpResult(name="x", regions={"Global": rr})
    return TelemetrySnapshot(seq=0, t=0.0, wall=0.0, result=res)


def test_with_child_metric_flows_through_all_exporters():
    """A metric registered via with_child appears in JSONL, Prometheus,
    and trace counters with zero exporter changes."""
    hier = HOST.with_child(
        "device_offload_efficiency",
        MetricSpec("queue_depth_eff", "Queue Depth Eff.",
                   lambda sd, dep: 0.25, multiplicative=False),
    )
    snap = _snapshot_with_hierarchy(hier)
    clk, mon = _monitored_run()
    exp = TelemetryExporter(mon)
    rec = exp.jsonl_record(snap)
    assert rec["regions"]["Global"]["host"]["queue_depth_eff"] == 0.25
    prom = exp.prometheus_text(snap)
    assert "# HELP talp_host_queue_depth_eff Queue Depth Eff." in prom
    assert "talp_host_queue_depth_eff" in prom
    counters = tx._counter_lines([(0.0, snap.result)], 0.0)
    assert any("queue_depth_eff" in l for l in counters)


# ---------------------------------------------------------------------------
# self-overhead accounting
# ---------------------------------------------------------------------------
def test_overhead_sections_accumulate():
    clk = FakeClock()
    acc = ovh.OverheadAccumulator(clock=clk)
    with acc.section("ingest"):
        clk.advance(0.5)
    with acc.section("ingest"):
        clk.advance(0.25)
    with acc.section("compact"):
        clk.advance(1.0)
    d = acc.as_dict()
    assert d["sections"]["ingest"] == pytest.approx(0.75)
    assert d["sections"]["compact"] == pytest.approx(1.0)
    assert d["counts"]["ingest"] == 2
    assert acc.total == pytest.approx(1.75)


def test_overhead_nested_sections_count_once():
    """Exclusive depth-0 total: nested sections don't double-charge."""
    clk = FakeClock()
    acc = ovh.OverheadAccumulator(clock=clk)
    with acc.section("sample"):
        clk.advance(1.0)
        with acc.section("flatten"):
            clk.advance(2.0)
    assert acc.totals["sample"] == pytest.approx(3.0)   # inclusive
    assert acc.totals["flatten"] == pytest.approx(2.0)
    assert acc.total == pytest.approx(3.0)              # exclusive outer


def test_overhead_fraction():
    clk = FakeClock()
    acc = ovh.OverheadAccumulator(clock=clk)
    with acc.section("ingest"):
        clk.advance(0.1)
    assert acc.fraction(2.0) == pytest.approx(0.05)
    assert acc.fraction(0.0) is None


def test_overhead_install_and_module_section():
    prev = ovh.current()
    clk = FakeClock()
    acc = ovh.OverheadAccumulator(clock=clk)
    try:
        old = ovh.install(acc)
        with ovh.section("spool"):
            clk.advance(0.5)
        assert acc.totals["spool"] == pytest.approx(0.5)
    finally:
        ovh.install(prev)
    # uninstalled module-level section is a harmless no-op
    ovh.install(None)
    try:
        with ovh.section("spool"):
            pass
    finally:
        ovh.install(prev)


def test_overhead_annotation_in_reports():
    clk = FakeClock()
    mon = TalpMonitor("o", clock=clk, overhead_report=True)
    with mon.region("w"):
        clk.advance(1.0)
    mon.ingest_device_arrays(0, DeviceActivity.KERNEL,
                             np.array([0.0]), np.array([0.5]))
    res = mon.finalize()
    g = res[TalpMonitor.GLOBAL]
    assert g.host.talp_overhead is not None
    assert 0.0 <= g.host.talp_overhead < 1.0
    assert "TALP Overhead" in render_text(g)
    d = json.loads(to_json(res))
    assert "talp_overhead" in d["regions"]["Global"]["host_metrics"]
    # sub-regions don't carry the annotation (Global-only measurement)
    assert "talp_overhead" not in d["regions"]["w"]["host_metrics"]


def test_overhead_absent_by_default():
    clk = FakeClock()
    mon = TalpMonitor("o", clock=clk)
    with mon.region("w"):
        clk.advance(1.0)
    res = mon.finalize()
    assert res[TalpMonitor.GLOBAL].host.talp_overhead is None
    d = json.loads(to_json(res))
    assert "talp_overhead" not in d["regions"]["Global"]["host_metrics"]
    assert "TALP Overhead" not in render_text(res[TalpMonitor.GLOBAL])


def _rank_result(rank, overhead):
    clk = FakeClock()
    mon = TalpMonitor("m", rank=rank, clock=clk, overhead_report=True)
    with mon.region("w"):
        clk.advance(1.0)
    g = mon.finalize()[TalpMonitor.GLOBAL]
    # pin the measured value for a deterministic merge assertion
    from repro.core.host_metrics import host_metrics
    st_ = [g.host_states[r] for r in sorted(g.host_states)]
    host = host_metrics(
        [s["useful"] for s in st_], [s["offload"] for s in st_],
        [s["mpi"] for s in st_], elapsed=g.elapsed,
        talp_overhead=overhead,
    )
    return RegionResult(
        name=g.name, elapsed=g.elapsed, n_ranks=g.n_ranks,
        n_devices=g.n_devices, host=host, device=g.device,
        host_states=g.host_states, device_states=g.device_states,
    )


def test_overhead_merge_carries_max():
    merged = merge_region_results(
        [_rank_result(0, 0.02), _rank_result(1, 0.07)])
    assert merged.host.talp_overhead == pytest.approx(0.07)


def test_overhead_merge_none_when_absent():
    clk = FakeClock()
    parts = []
    for rank in (0, 1):
        mon = TalpMonitor("m", rank=rank, clock=clk)
        with mon.region("w"):
            clk.advance(1.0)
        parts.append(mon.finalize()[TalpMonitor.GLOBAL])
    merged = merge_region_results(parts)
    assert merged.host.talp_overhead is None


def test_overhead_json_roundtrip():
    clk = FakeClock()
    mon = TalpMonitor("o", clock=clk, overhead_report=True)
    with mon.region("w"):
        clk.advance(1.0)
    res = mon.finalize()
    d = from_json(to_json(res))
    rr = region_result_from_dict(d["regions"]["Global"])
    want = res[TalpMonitor.GLOBAL].host.talp_overhead
    assert rr.host.talp_overhead == pytest.approx(want)


# ---------------------------------------------------------------------------
# watchdog publication + step-resolution trace tracks
# ---------------------------------------------------------------------------
def test_exporter_port_property():
    clk, mon = _monitored_run()
    exp = TelemetryExporter(mon)
    assert exp.port is None            # not serving yet
    port = exp.serve(port=0)           # ephemeral: OS picks a free port
    try:
        assert port > 0
        assert exp.port == port
        assert exp.serve() == port     # idempotent while running
    finally:
        exp.close()
    assert exp.port is None


def test_exporter_publishes_watchdog_state():
    from repro.core.telemetry.watchdog import EfficiencyWatchdog

    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    for i in range(20):
        wd.observe(region="step", step=i, t=float(i),
                   values={"host_parallel_efficiency": 0.9})
    wd.observe(region="step", step=20, t=20.0,
               values={"host_parallel_efficiency": 0.4})
    assert len(wd.events) == 1

    clk, mon = _monitored_run()
    buf = io.StringIO()
    exp = TelemetryExporter(mon, jsonl=buf, watchdog=wd)
    exp.sample()
    rec = json.loads(buf.getvalue().splitlines()[-1])
    assert rec["watchdog"]["n_events"] == 1
    assert rec["watchdog"]["firing"] == [
        {"region": "step", "metric": "host_parallel_efficiency"}
    ]
    prom = exp.prometheus_text()
    assert "# TYPE talp_watchdog_events_total counter" in prom
    assert 'talp_watchdog_events_total{trace="run"} 1' in prom
    assert ('talp_watchdog_firing{region="step",'
            'metric="host_parallel_efficiency",trace="run"} 1') in prom
    exp.close()


def test_trace_step_counters_and_anomaly_markers():
    """A step series switches the counter tracks to step resolution and
    watchdog anomalies become instant markers — and the result still
    passes the structural validator."""
    from repro.core.telemetry.watchdog import synthetic_drift_scenario

    sc = synthetic_drift_scenario(steps=40)
    wd = sc["watchdog"]
    series = sc["recorder"].series
    assert wd.events and len(series) > 0
    trace = tx.export_monitor(
        sc["monitor"], result=sc["result"],
        step_series=series, anomalies=wd.events,
    )
    stats = tx.validate_chrome_trace(trace)
    # one instant marker per anomaly, on the anomalies process lane
    assert stats["counts"]["i"] == len(wd.events)
    # one counter event per (row, hierarchy): host + device per step row
    assert stats["counts"]["C"] == 2 * len(series)
    assert "talp anomalies" in trace
    assert "talp:anomaly:device:load_balance" in trace
    assert f"{tx.PID_ANOMALIES}" in trace


def test_trace_step_counters_supersede_cadence_samples():
    """With both polling samples and a step series, only the
    step-resolution counters are emitted."""
    from repro.core.telemetry.stepseries import StepSeriesRecorder

    clk, mon = _monitored_run()
    rec = StepSeriesRecorder(mon, capacity=16, regions=("step",))
    samples = []
    for _ in range(3):
        with mon.region("step"):
            clk.advance(0.1)
        samples.append((clk.t, mon.sample_result()))
    result = mon.finalize()
    trace = tx.export_monitor(
        mon, result=result, samples=samples, step_series=rec.series)
    stats = tx.validate_chrome_trace(trace)
    # 3 step rows x 2 hierarchy groups — exactly the step-resolution
    # counters; the cadence track (3 samples x every region x hierarchy)
    # would have added more
    assert stats["counts"]["C"] == 2 * len(rec.series)
    assert "talp:host:step" in trace
