"""Test bootstrap.

Prefers a real ``hypothesis`` installation; when the environment has none
(air-gapped CI images), falls back to the minimal API-compatible shim
vendored under ``tests/_vendor`` so the property tests still collect and
run (without shrinking).
"""

import sys
from pathlib import Path

try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, str(Path(__file__).resolve().parent / "_vendor"))
