"""Strategy objects for the vendored ``hypothesis`` fallback.

Each strategy wraps a ``sample(rng)`` function. Draws are biased toward
boundary values (bounds, zero, small integers) so the cheap fallback still
exercises the edge cases real hypothesis would find quickly.
"""

from __future__ import annotations

import math
import random
from typing import Any, Callable, List, Optional, Sequence

__all__ = [
    "SearchStrategy",
    "integers",
    "floats",
    "lists",
    "booleans",
    "sampled_from",
    "tuples",
    "just",
    "composite",
]

_EDGE_PROB = 0.15  # chance of drawing a boundary value instead of uniform


class SearchStrategy:
    def __init__(self, sampler: Callable[[random.Random], Any], label: str = ""):
        self._sampler = sampler
        self._label = label or "strategy"

    def sample(self, rng: random.Random) -> Any:
        return self._sampler(rng)

    def map(self, f: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: f(self.sample(rng)),
                              f"{self._label}.map")

    def filter(self, pred: Callable[[Any], bool],
               max_tries: int = 1000) -> "SearchStrategy":
        def sampler(rng: random.Random):
            for _ in range(max_tries):
                v = self.sample(rng)
                if pred(v):
                    return v
            raise RuntimeError(f"filter on {self._label} rejected "
                               f"{max_tries} consecutive draws")

        return SearchStrategy(sampler, f"{self._label}.filter")

    def __repr__(self) -> str:
        return f"<{self._label}>"


def integers(min_value: Optional[int] = None,
             max_value: Optional[int] = None) -> SearchStrategy:
    lo = -(2 ** 16) if min_value is None else int(min_value)
    hi = 2 ** 16 if max_value is None else int(max_value)
    if lo > hi:
        raise ValueError(f"integers: min {lo} > max {hi}")
    edges = sorted({lo, hi, *(v for v in (0, 1, -1) if lo <= v <= hi)})

    def sampler(rng: random.Random) -> int:
        if rng.random() < _EDGE_PROB:
            return rng.choice(edges)
        return rng.randint(lo, hi)

    return SearchStrategy(sampler, f"integers({lo}, {hi})")


def floats(min_value: Optional[float] = None,
           max_value: Optional[float] = None,
           allow_nan: bool = True,
           allow_infinity: bool = True,
           allow_subnormal: bool = True,
           width: int = 64) -> SearchStrategy:
    bounded = min_value is not None and max_value is not None
    lo = -1e9 if min_value is None else float(min_value)
    hi = 1e9 if max_value is None else float(max_value)
    if lo > hi:
        raise ValueError(f"floats: min {lo} > max {hi}")
    edges: List[float] = [lo, hi]
    if lo <= 0.0 <= hi:
        edges.append(0.0)
    if lo <= 1.0 <= hi:
        edges.append(1.0)
    specials: List[float] = []
    if not bounded:
        if allow_nan:
            specials.append(math.nan)
        if allow_infinity:
            specials.extend([math.inf, -math.inf])

    def sampler(rng: random.Random) -> float:
        r = rng.random()
        if specials and r < 0.05:
            return rng.choice(specials)
        if r < _EDGE_PROB:
            return rng.choice(edges)
        if rng.random() < 0.2:
            # small-magnitude values near the low edge: catches
            # degenerate/zero-length interval and duration cases
            return lo + (hi - lo) * (10.0 ** rng.uniform(-12, -1))
        return rng.uniform(lo, hi)

    return SearchStrategy(sampler, f"floats({lo}, {hi})")


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: Optional[int] = None,
          unique: bool = False) -> SearchStrategy:
    if max_size is None:
        max_size = min_size + 10

    def sampler(rng: random.Random) -> list:
        n = rng.randint(min_size, max_size)
        if not unique:
            return [elements.sample(rng) for _ in range(n)]
        out: list = []
        tries = 0
        while len(out) < n and tries < 100 * (n + 1):
            v = elements.sample(rng)
            tries += 1
            if v not in out:
                out.append(v)
        return out

    return SearchStrategy(sampler, f"lists({elements!r})")


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5, "booleans()")


def sampled_from(options: Sequence[Any]) -> SearchStrategy:
    options = list(options)
    if not options:
        raise ValueError("sampled_from: empty sequence")
    return SearchStrategy(lambda rng: rng.choice(options), "sampled_from")


def tuples(*strategies: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(
        lambda rng: tuple(s.sample(rng) for s in strategies), "tuples"
    )


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value, f"just({value!r})")


def composite(f: Callable) -> Callable[..., SearchStrategy]:
    """``@st.composite``: first parameter of ``f`` becomes ``draw``."""

    def builder(*args, **kwargs) -> SearchStrategy:
        def sampler(rng: random.Random):
            def draw(strategy: SearchStrategy):
                return strategy.sample(rng)

            return f(draw, *args, **kwargs)

        return SearchStrategy(sampler, f"composite:{f.__name__}")

    builder.__name__ = f.__name__
    return builder
