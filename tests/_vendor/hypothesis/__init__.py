"""Minimal, API-compatible fallback for the ``hypothesis`` property-testing
library, used only when the real package is not installed (see
``tests/conftest.py``).

Implements the subset this repo's tests use:

  * ``@given(*strategies)`` — runs the test for ``max_examples`` pseudo-random
    draws (deterministic per test, seeded from the test name);
  * ``@settings(max_examples=..., deadline=...)`` — composable above or below
    ``@given``;
  * ``hypothesis.strategies`` — ``integers``, ``floats``, ``lists``,
    ``booleans``, ``sampled_from``, ``tuples``, ``just``, ``composite`` with
    ``.map``/``.filter``.

No shrinking, no database, no deadlines: on failure the falsifying example is
printed and the original exception propagates.
"""

from __future__ import annotations

import functools
import random
import sys
import zlib

from . import strategies

__all__ = ["given", "settings", "strategies", "HealthCheck"]

__version__ = "0.0.0+repro-fallback"


class HealthCheck:
    """Placeholder for ``hypothesis.HealthCheck`` (suppression is a no-op)."""

    all = staticmethod(lambda: [])
    too_slow = "too_slow"
    filter_too_much = "filter_too_much"
    data_too_large = "data_too_large"


class settings:
    """Decorator carrying example-count configuration.

    Works both above and below ``@given``: it simply attaches itself to
    whatever callable it wraps; the ``given`` runner looks the attribute up
    at call time.
    """

    default_max_examples = 100

    def __init__(self, max_examples: int = None, deadline=None,
                 suppress_health_check=(), derandomize: bool = False,
                 print_blob: bool = False):
        self.max_examples = (
            self.default_max_examples if max_examples is None else max_examples
        )
        self.deadline = deadline  # accepted, ignored (no deadline enforcement)

    def __call__(self, fn):
        fn._hypothesis_settings = self
        return fn


class _HypothesisHandle:
    def __init__(self, inner_test):
        self.inner_test = inner_test


def _resolve_settings(runner, inner):
    return getattr(
        runner, "_hypothesis_settings",
        getattr(inner, "_hypothesis_settings", None),
    ) or settings()


def given(*arg_strategies, **kw_strategies):
    """Run the wrapped test for each of ``max_examples`` drawn inputs."""
    for s in list(arg_strategies) + list(kw_strategies.values()):
        if not isinstance(s, strategies.SearchStrategy):
            raise TypeError(f"@given expects strategies, got {s!r}")

    def decorate(fn):
        @functools.wraps(fn)
        def runner(*args, **kwargs):
            cfg = _resolve_settings(runner, fn)
            # Deterministic per-test stream so failures are reproducible.
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            for example in range(cfg.max_examples):
                drawn = [s.sample(rng) for s in arg_strategies]
                drawn_kw = {k: s.sample(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except Exception:
                    print(
                        f"Falsifying example ({fn.__qualname__}, "
                        f"example {example}): args={drawn!r} kwargs={drawn_kw!r}",
                        file=sys.stderr,
                    )
                    raise

        # Keep name/doc but hide the inner signature: pytest must see
        # (*args, **kwargs), not the drawn parameters, or it would try to
        # resolve them as fixtures.
        del runner.__wrapped__
        # Parity with real hypothesis: plugins unwrap via `.hypothesis.inner_test`.
        runner.hypothesis = _HypothesisHandle(fn)
        return runner

    return decorate
