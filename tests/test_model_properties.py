"""Hypothesis property tests on model-level invariants:

  * causality — perturbing future tokens never changes past logits,
    for full, windowed and local/global attention and for SSM mixers;
  * MoE conservation — dispatch assigns each (token, k) at most one
    (expert, capacity) slot; combine weights are bounded by the gates;
  * GQA equivalence — attention with K kv-heads equals MHA where the
    kv-heads are explicitly repeated.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import smoke_config
from repro.kernels.flash_attention.ref import attention_reference
from repro.models import lm
from repro.models.moe import moe_capacity, moe_forward


ARCHS_CAUSAL = ["llama3.2-3b", "h2o-danube-3-4b", "gemma2-2b",
                "mamba2-130m", "zamba2-2.7b"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS_CAUSAL)
def test_causality(arch):
    """logits[:, :t] must be invariant to tokens[:, t:]."""
    cfg = smoke_config(arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    t_split = 24
    toks1 = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                               cfg.vocab_size, jnp.int32)
    toks2 = toks1.at[:, t_split:].set(
        jax.random.randint(jax.random.PRNGKey(2), (2, 64 - t_split), 0,
                           cfg.vocab_size, jnp.int32))

    def logits_all(toks):
        # full-sequence logits via the training path internals
        x = lm._embed(cfg, params, toks)
        x, _, _ = lm._stack_fwd(cfg, params, x,
                                lm._positions(cfg, 2, 64))
        from repro.models.common import rms_norm
        h = rms_norm(x, params["final_norm"])
        return lm._logits(cfg, params, h)

    l1 = np.asarray(logits_all(toks1), np.float32)[:, :t_split]
    l2 = np.asarray(logits_all(toks2), np.float32)[:, :t_split]
    np.testing.assert_allclose(l1, l2, rtol=1e-3, atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(1, 3), st.integers(0, 1000))
def test_attention_causality_property(heads_pow, kv_div, seed):
    """Random GQA shapes: zeroing future keys/values never changes a
    causal attention output at earlier positions."""
    h = 2 ** heads_pow // 2 or 1
    k = max(1, h // kv_div)
    h = k * (h // k) or k
    d, s, b = 16, 32, 1
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    kk = jax.random.normal(ks[1], (b, s, k, d))
    vv = jax.random.normal(ks[2], (b, s, k, d))
    out1 = attention_reference(q, kk, vv, causal=True)
    kk2 = kk.at[:, s // 2:].set(0.0)
    vv2 = vv.at[:, s // 2:].set(0.0)
    out2 = attention_reference(q, kk2, vv2, causal=True)
    np.testing.assert_allclose(
        np.asarray(out1[:, : s // 2]), np.asarray(out2[:, : s // 2]),
        rtol=1e-5, atol=1e-5,
    )


def test_gqa_equals_repeated_mha():
    b, s, k, g, d = 1, 32, 2, 3, 16
    h = k * g
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    kk = jax.random.normal(ks[1], (b, s, k, d))
    vv = jax.random.normal(ks[2], (b, s, k, d))
    out_gqa = attention_reference(q, kk, vv, causal=True)
    kk_rep = jnp.repeat(kk, g, axis=2)
    vv_rep = jnp.repeat(vv, g, axis=2)
    # query head i uses kv head i // g — construct matching MHA order
    out_mha = attention_reference(
        q.reshape(b, s, k, g, d).reshape(b, s, h, d), kk_rep, vv_rep,
        causal=True)
    np.testing.assert_allclose(np.asarray(out_gqa), np.asarray(out_mha),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 10_000))
def test_moe_conservation(seed):
    """Output tokens not routed anywhere (dropped) come out as exactly
    zero (the residual carries them); routed outputs are finite; aux
    loss ≥ 1 (its minimum at perfect balance)."""
    cfg = smoke_config("granite-moe-3b-a800m")
    key = jax.random.PRNGKey(seed)
    from repro.models.moe import init_moe_params
    p = init_moe_params(key, cfg)
    x = jax.random.normal(jax.random.split(key)[1], (2, 64, cfg.d_model),
                          jnp.float32)
    y, aux = moe_forward(cfg, p, x)
    assert y.shape == x.shape
    assert np.all(np.isfinite(np.asarray(y, np.float32)))
    assert float(aux) >= 0.99  # E * Σ f_i p_i ≥ 1 at balance


def test_moe_capacity_formula():
    cfg = smoke_config("qwen3-moe-235b-a22b")
    c = moe_capacity(cfg, 64)
    # ceil(64·k/E·cf) rounded to multiple of 4, min 4
    assert c >= 4 and c % 4 == 0
