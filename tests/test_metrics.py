"""Tests for POP (eqs. 1–5), host (eqs. 6–8) and device (eqs. 9–12) metrics."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (
    analyze_trace,
    device_metrics,
    elapsed_time,
    host_metrics,
    pop_metrics,
)
from repro.core.backends import SyntheticTraceBuilder
from repro.core.tree import device_tree, host_tree


durations = st.lists(
    st.floats(0.0, 1e3, allow_nan=False, allow_infinity=False),
    min_size=1,
    max_size=16,
)


# ---------------------------------------------------------------------------
# POP MPI metrics (eqs. 1–5)
# ---------------------------------------------------------------------------
def test_elapsed_time_eq1():
    assert elapsed_time([1, 2], [3, 1]) == pytest.approx(4.0)


def test_pop_perfect():
    m = pop_metrics([2.0, 2.0], [0.0, 0.0])
    assert m.parallel_efficiency == pytest.approx(1.0)
    assert m.load_balance == pytest.approx(1.0)
    assert m.communication_efficiency == pytest.approx(1.0)


def test_pop_imbalance():
    # rank0 works 4s, rank1 works 2s then waits 2s in MPI
    m = pop_metrics([4.0, 2.0], [0.0, 2.0])
    assert m.elapsed == pytest.approx(4.0)
    assert m.parallel_efficiency == pytest.approx(6 / 8)
    assert m.load_balance == pytest.approx(6 / 8)
    assert m.communication_efficiency == pytest.approx(1.0)
    m.validate()


def test_pop_communication_loss():
    # both ranks compute 2s and spend 2s in MPI: pure comm loss
    m = pop_metrics([2.0, 2.0], [2.0, 2.0])
    assert m.parallel_efficiency == pytest.approx(0.5)
    assert m.load_balance == pytest.approx(1.0)
    assert m.communication_efficiency == pytest.approx(0.5)
    m.validate()


@settings(max_examples=200, deadline=None)
@given(durations, durations)
def test_pop_properties(u, nu):
    n = min(len(u), len(nu))
    u, nu = u[:n], nu[:n]
    if sum(u) + sum(nu) <= 0 or max(ui + nui for ui, nui in zip(u, nu)) <= 0:
        return
    m = pop_metrics(u, nu)
    assert 0.0 <= m.parallel_efficiency <= 1.0 + 1e-9
    assert 0.0 <= m.load_balance <= 1.0 + 1e-9
    assert 0.0 <= m.communication_efficiency <= 1.0 + 1e-9
    m.validate(tol=1e-7)


# ---------------------------------------------------------------------------
# Host hierarchy (eqs. 6–8)
# ---------------------------------------------------------------------------
def test_host_metrics_eqs_6_7_8():
    # rank0: U=2, W=1, MPI=1 → total 4 ; rank1: U=1, W=2, MPI=1 → total 4
    m = host_metrics([2.0, 1.0], [1.0, 2.0], [1.0, 1.0])
    assert m.elapsed == pytest.approx(4.0)
    assert m.parallel_efficiency == pytest.approx(3.0 / 8.0)      # eq 6
    assert m.mpi_parallel_efficiency == pytest.approx(6.0 / 8.0)  # eq 7
    assert m.device_offload_efficiency == pytest.approx(3.0 / 6.0)  # eq 8
    m.validate()


def test_host_offload_counts_as_useful_for_mpi_lb():
    """Paper use case 3: no useful-time imbalance but offload imbalance
    still shows as MPI-level load imbalance (intended semantics)."""
    # equal useful, very different offload
    m = host_metrics([1.0, 1.0], [8.0, 0.0], [0.0, 8.0])
    assert m.load_balance == pytest.approx((9 + 1) / (2 * 9))
    assert m.load_balance < 0.6  # imbalanced at MPI level


@settings(max_examples=200, deadline=None)
@given(durations, durations, durations)
def test_host_multiplicative(u, w, mp):
    n = min(len(u), len(w), len(mp))
    u, w, mp = u[:n], w[:n], mp[:n]
    if max(ui + wi + mi for ui, wi, mi in zip(u, w, mp)) <= 0:
        return
    if sum(ui + wi for ui, wi in zip(u, w)) <= 0:
        return
    m = host_metrics(u, w, mp)
    m.validate(tol=1e-7)
    host_tree(m).validate(tol=1e-6)
    for v in (m.parallel_efficiency, m.mpi_parallel_efficiency,
              m.load_balance, m.communication_efficiency,
              m.device_offload_efficiency):
        assert 0.0 <= v <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# Device hierarchy (eqs. 9–12)
# ---------------------------------------------------------------------------
def test_device_metrics_eqs_9_12():
    # dev0: K=4, M=1 ; dev1: K=2, M=2 ; E=6
    m = device_metrics([4.0, 2.0], [1.0, 2.0], elapsed=6.0)
    assert m.parallel_efficiency == pytest.approx(6.0 / 12.0)        # eq 9
    assert m.load_balance == pytest.approx(6.0 / 8.0)                # eq 10
    assert m.communication_efficiency == pytest.approx(4.0 / 5.0)    # eq 11
    assert m.orchestration_efficiency == pytest.approx(5.0 / 6.0)    # eq 12
    m.validate()


def test_device_all_idle():
    m = device_metrics([0.0, 0.0], [0.0, 0.0], elapsed=1.0)
    assert m.parallel_efficiency == 0.0
    assert m.orchestration_efficiency == 0.0


@settings(max_examples=200, deadline=None)
@given(durations, durations, st.floats(1e-3, 1e4))
def test_device_multiplicative(k, mem, extra):
    n = min(len(k), len(mem))
    k, mem = k[:n], mem[:n]
    elapsed = max(ki + mi for ki, mi in zip(k, mem)) + extra
    m = device_metrics(k, mem, elapsed)
    m.validate(tol=1e-7)
    device_tree(m).validate(tol=1e-6)
    for v in (m.parallel_efficiency, m.load_balance,
              m.communication_efficiency, m.orchestration_efficiency):
        assert 0.0 <= v <= 1.0 + 1e-9


# ---------------------------------------------------------------------------
# Trace → metrics, including the flattening pipeline
# ---------------------------------------------------------------------------
def test_analyze_trace_overlap_counts_as_computation():
    """Overlapping kernel+memory streams: overlap must count as kernel."""
    b = SyntheticTraceBuilder(nranks=1, ndevices=1)
    b.rank(0).offload(4.0)
    b.device_kernel(0, 0.0, 2.0, stream=0)   # [0, 2]
    b.device_kernel(0, 1.0, 2.0, stream=1)   # [1, 3] overlaps: flatten to [0,3]
    b.device_memory(0, 2.0, 2.0)             # [2, 4]: overlap [2,3] removed → 1s
    tr = b.build()
    res = analyze_trace(tr)
    st = res.device_states[0]
    assert st["kernel"] == pytest.approx(3.0)
    assert st["memory"] == pytest.approx(1.0)
    assert st["idle"] == pytest.approx(0.0)
    assert res.device.orchestration_efficiency == pytest.approx(1.0)
    res.validate()


def test_analyze_trace_elapsed_eq1():
    b = SyntheticTraceBuilder(nranks=2, ndevices=2)
    b.rank(0).useful(3.0)
    b.rank(1).useful(1.0)
    b.barrier()
    tr = b.build()
    res = analyze_trace(tr)
    assert res.elapsed == pytest.approx(3.0)
    assert res.host.load_balance == pytest.approx(4.0 / 6.0)
    assert res.host.communication_efficiency == pytest.approx(1.0)
    res.validate()
