"""End-to-end behaviour tests for the full system: training converges
under TALP monitoring with checkpointing; serving generates tokens; the
TALP reports produced by real runs satisfy the paper's invariants."""

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.serve import serve
from repro.launch.train import train
from repro.models import lm
from repro.optim.adamw import AdamWConfig


@pytest.mark.slow
def test_train_loss_decreases_with_talp(tmp_path):
    cfg = smoke_config("gemma2-2b")
    state, history, talp = train(
        cfg,
        steps=30,
        global_batch=4,
        seq_len=64,
        ckpt_dir=str(tmp_path),
        ckpt_every=10,
        verbose=False,
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30),
    )
    losses = [h["loss"] for h in history]
    assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]
    # TALP report exists and satisfies the multiplicative hierarchy
    loop = talp.regions["train_loop"]
    assert loop.host is not None and loop.device is not None
    loop.host.validate(tol=1e-6)
    loop.device.validate(tol=1e-6)
    assert loop.host_states[0]["useful"] > 0
    assert loop.host_states[0]["offload"] > 0
    assert loop.device_states[0]["kernel"] > 0


@pytest.mark.slow
def test_serve_generates_and_reports(tmp_path):
    cfg = smoke_config("h2o-danube-3-4b")   # SWA ring-cache path
    tokens, talp = serve(cfg, requests=2, prompt_len=16, gen_len=6,
                         verbose=False)
    assert tokens.shape == (2, 6)
    assert np.all(tokens >= 0) and np.all(tokens < cfg.vocab_size)
    dec = talp.regions["decode"]
    dec.host.validate(tol=1e-6)
    assert dec.device_states[0]["kernel"] > 0


@pytest.mark.slow
def test_embed_frontend_end_to_end():
    """VLM/audio stub frontends train and serve (backbone-only)."""
    cfg = smoke_config("musicgen-large")
    _, history, _ = train(cfg, steps=8, global_batch=2, seq_len=32,
                          verbose=False)
    assert np.isfinite(history[-1]["loss"])
    tokens, _ = serve(cfg, requests=2, prompt_len=8, gen_len=3,
                      verbose=False)
    assert tokens.shape == (2, 3)


def test_consolidate_caches_roundtrip():
    """Hot-ring flush: decode → consolidate → decode equals continuous
    decode (serving-layer contract)."""
    import jax
    import jax.numpy as jnp

    cfg = smoke_config("llama3.2-3b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                              cfg.vocab_size, jnp.int32)
    _, caches, pos = lm.prefill(cfg, params, toks[:, :16])
    caches = lm.grow_caches(cfg, caches, 24)

    # path 1: straight decode of 4 tokens
    c1, p1 = caches, pos
    for t in range(16, 20):
        l1, c1, p1 = lm.decode_step(cfg, params, toks[:, t:t+1], p1, c1)

    # path 2: decode 2, consolidate (flush hot ring), decode 2
    c2, p2 = caches, pos
    for t in range(16, 18):
        _, c2, p2 = lm.decode_step(cfg, params, toks[:, t:t+1], p2, c2)
    c2 = lm.consolidate_caches(cfg, c2)
    for t in range(18, 20):
        l2, c2, p2 = lm.decode_step(cfg, params, toks[:, t:t+1], p2, c2)

    np.testing.assert_allclose(
        np.asarray(l1, np.float32), np.asarray(l2, np.float32),
        rtol=2e-2, atol=2e-2,
    )
