"""Step-resolution metric series: ring semantics, per-window recording
through the monitor's region-close hook, spool round trip, and the
rank-aligned job-level step merge."""

import math

import numpy as np
import pytest

from repro.core.backends.analytical import HardwareSpec, StepModel
from repro.core.hierarchy import HOST, MetricSpec, StateDurations
from repro.core.merge import FileSpoolTransport, merge_step_series
from repro.core.states import DeviceActivity
from repro.core.talp import TalpMonitor
from repro.core.telemetry.stepseries import (
    BASE_FIELDS,
    DEFAULT_HIERARCHIES,
    StepSeries,
    StepSeriesRecorder,
    metric_columns_of,
)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def _monitor(**kw):
    clk = FakeClock()
    mon = TalpMonitor("run", clock=clk, auto_start=True, **kw)
    return clk, mon


def _assert_rows_equal(a, b):
    """Field-wise equality for structured row arrays (NaN == NaN)."""
    assert (a.dtype.names or ()) == (b.dtype.names or ())
    for f in a.dtype.names or ():
        np.testing.assert_array_equal(a[f], b[f], err_msg=f"field {f!r}")


# ---------------------------------------------------------------------------
# schema generality
# ---------------------------------------------------------------------------
def test_metric_columns_prefixed_per_hierarchy():
    cols = metric_columns_of(DEFAULT_HIERARCHIES)
    assert "host_parallel_efficiency" in cols
    assert "device_load_balance" in cols
    # every column carries its hierarchy prefix
    assert all(c.startswith(("host_", "device_")) for c in cols)


def test_with_child_metric_becomes_a_column():
    hier = HOST.with_child(
        "device_offload_efficiency",
        MetricSpec("queue_depth_eff", "Queue Depth Eff.",
                   lambda sd, dep: 0.25, multiplicative=False),
    )
    s = StepSeries(capacity=4, hierarchies=(hier,))
    assert "host_queue_depth_eff" in s.metric_columns
    s.append("step", 0, 0.0, 1.0, values={"host_queue_depth_eff": 0.25})
    assert s.column("host_queue_depth_eff")[0] == 0.25


def test_append_missing_values_are_nan_and_unknown_keys_ignored():
    s = StepSeries(capacity=4)
    s.append("step", 0, 0.0, 1.0,
             values={"host_parallel_efficiency": 0.5, "no_such_column": 9.0})
    assert s.column("host_parallel_efficiency")[0] == 0.5
    assert math.isnan(s.column("device_load_balance")[0])
    assert "no_such_column" not in (s.rows().dtype.names or ())


# ---------------------------------------------------------------------------
# bounded ring
# ---------------------------------------------------------------------------
def test_ring_wraparound_keeps_newest_and_counts_dropped():
    s = StepSeries(capacity=4)
    for i in range(10):
        s.append("step", i, float(i), float(i) + 0.5)
    assert len(s) == 4
    assert s.n_total == 10
    assert s.n_dropped == 6
    rows = s.rows()
    # chronological order, oldest retained row first
    assert list(rows["step"]) == [6, 7, 8, 9]
    assert np.all(rows["elapsed"] == 0.5)


def test_capacity_must_be_positive():
    with pytest.raises(ValueError, match="capacity"):
        StepSeries(capacity=0)


def test_column_region_filter():
    s = StepSeries(capacity=8)
    s.append("a", 0, 0.0, 1.0)
    s.append("b", 0, 1.0, 3.0)
    s.append("a", 1, 3.0, 4.0)
    assert list(s.column("elapsed", region="a")) == [1.0, 1.0]
    assert list(s.column("elapsed", region="b")) == [2.0]
    assert s.region_names == ("a", "b")


# ---------------------------------------------------------------------------
# recorder: per-window deltas, device columns, lifecycle
# ---------------------------------------------------------------------------
def test_recorder_rows_carry_per_window_deltas_not_cumulative():
    clk, mon = _monitor()
    rec = StepSeriesRecorder(mon, capacity=16)
    mpis = [0.1, 0.3, 0.2]
    for mpi_s in mpis:
        with mon.region("step"):
            clk.advance(0.5)                      # useful
            with mon.mpi():
                clk.advance(mpi_s)
    rows = rec.series.column("mpi", region="step")
    # each row is exactly that window's delta, not the running total
    assert rows == pytest.approx(mpis)
    assert rec.series.column("useful", region="step") == pytest.approx(
        [0.5] * 3)
    assert list(rec.series.column("step", region="step")) == [0, 1, 2]
    mon.finalize()


def test_recorder_host_metrics_match_hierarchy_engine():
    clk, mon = _monitor()
    rec = StepSeriesRecorder(mon, capacity=16)
    with mon.region("step"):
        clk.advance(0.6)
        with mon.offload():
            clk.advance(0.3)
        with mon.mpi():
            clk.advance(0.1)
    row = rec.series.rows()[0]
    sd = StateDurations(elapsed=1.0, useful=[0.6], offload=[0.3], mpi=[0.1])
    expect = HOST.compute(sd).values
    for key, val in expect.items():
        got = float(row[f"host_{key}"])
        if val is None:
            assert math.isnan(got)
        else:
            assert got == pytest.approx(val)
    mon.finalize()


def test_recorder_device_columns_windowed_and_ce_extra():
    peak, model_flops = 100e12, 1e12
    fm = StepModel(flops=0.0, hbm_bytes=0.0, collective_bytes=0.0,
                   model_flops=model_flops,
                   hw=HardwareSpec(name="t", peak_flops=peak))
    clk, mon = _monitor(flop_model=fm)
    rec = StepSeriesRecorder(mon, capacity=16)
    busies = [(0.4, 0.2), (0.3, 0.3)]   # (dev0, dev1) kernel busy per step
    for k0, k1 in busies:
        with mon.region("step"):
            t0 = clk.t
            mon.add_device_record(0, DeviceActivity.KERNEL, t0, t0 + k0)
            mon.add_device_record(1, DeviceActivity.KERNEL, t0, t0 + k1)
            clk.advance(1.0)
    rows = rec.series.rows()
    # per-window device load balance: mean(busy)/max(busy) inside the step
    lb = rows["device_load_balance"]
    assert lb[0] == pytest.approx(0.3 / 0.4)
    assert lb[1] == pytest.approx(1.0)
    # CE annotation comes from the monitor's flop model (cumulative over
    # the flattened timelines at that close)
    ce = rows["device_computational_efficiency"]
    assert np.isfinite(ce).all()
    # at the first close: 2 launches, busy = 0.6 s
    assert ce[0] == pytest.approx(2 * model_flops / (peak * 0.6))
    mon.finalize()


def test_recorder_region_filter_and_nested_regions():
    clk, mon = _monitor()
    rec = StepSeriesRecorder(mon, capacity=16, regions=("inner",))
    with mon.region("outer"):
        for _ in range(3):
            with mon.region("inner"):
                clk.advance(0.1)
        clk.advance(0.2)
    assert len(rec.series) == 3
    assert rec.series.region_names == ("inner",)
    mon.finalize()


def test_recorder_close_detaches_idempotently():
    clk, mon = _monitor()
    rec = StepSeriesRecorder(mon, capacity=16)
    with mon.region("step"):
        clk.advance(0.1)
    rec.close()
    rec.close()   # idempotent
    with mon.region("step"):
        clk.advance(0.1)
    assert len(rec.series) == 1
    mon.finalize()


def test_recorder_cost_charged_to_step_overhead_section():
    clk, mon = _monitor(overhead_report=True)
    StepSeriesRecorder(mon, capacity=16)
    n = 5
    for _ in range(n):
        with mon.region("step"):
            clk.advance(0.1)
    assert mon.overhead.counts["step"] == n
    assert mon.overhead.totals["step"] >= 0.0
    res = mon.finalize()
    assert res.regions[TalpMonitor.GLOBAL].host.talp_overhead is not None


def test_recorder_zero_elapsed_window_skipped():
    clk, mon = _monitor()
    rec = StepSeriesRecorder(mon, capacity=16)
    with mon.region("step"):
        pass   # clock does not move
    assert len(rec.series) == 0
    mon.finalize()


# ---------------------------------------------------------------------------
# spool round trip
# ---------------------------------------------------------------------------
def test_to_arrays_from_arrays_round_trip_preserves_everything():
    s = StepSeries(capacity=3)
    for i in range(5):   # wraps: rows 2..4 retained, 2 dropped
        s.append("step" if i % 2 == 0 else "other", i, float(i), i + 1.0,
                 useful=0.5, offload=0.3, mpi=0.2,
                 values={"host_parallel_efficiency": 0.9})
    back = StepSeries.from_arrays(**s.to_arrays())
    assert len(back) == len(s) == 3
    assert back.n_total == 5 and back.n_dropped == 2
    assert back.region_names == s.region_names
    assert back.metric_columns == s.metric_columns
    _assert_rows_equal(back.rows(), s.rows())
    # region filtering still works on the reconstructed series
    assert list(back.column("step", region="other")) == [3]


def test_as_table_renders_rows_and_nan_dash():
    s = StepSeries(capacity=4)
    s.append("step", 0, 0.0, 1.0, values={"host_parallel_efficiency": 0.75})
    text = s.as_table()
    assert "host_parallel_efficiency" in text
    assert "0.7500" in text
    assert "-" in text   # NaN metric renders as a dash


# ---------------------------------------------------------------------------
# job-level step merge
# ---------------------------------------------------------------------------
def _rank_series(useful_by_step, offload=0.2, mpi=0.1, device_lb=None):
    s = StepSeries(capacity=16)
    t = 0.0
    for i, u in enumerate(useful_by_step):
        vals = {}
        if device_lb is not None:
            vals["device_load_balance"] = device_lb[i]
        s.append("step", i, t, t + 1.0, useful=u, offload=offload, mpi=mpi,
                 values=vals)
        t += 1.0
    return s


def test_merge_step_series_recomputes_host_not_averages():
    # asymmetric ranks: recomputed job-level load balance differs from the
    # mean of the (identical, per-rank-trivial) rank values
    s0 = _rank_series([0.7, 0.7])
    s1 = _rank_series([0.3, 0.5])
    job = merge_step_series({0: s0, 1: s1})
    rows = job.rows()
    assert list(rows["n_ranks"]) == [2.0, 2.0]
    # base durations are across-rank sums
    assert rows["useful"] == pytest.approx([1.0, 1.2])
    for i, (u0, u1) in enumerate([(0.7, 0.3), (0.7, 0.5)]):
        sd = StateDurations(elapsed=1.0, useful=[u0, u1],
                            offload=[0.2, 0.2], mpi=[0.1, 0.1])
        expect = HOST.compute(sd).values
        assert float(rows["host_load_balance"][i]) == pytest.approx(
            expect["load_balance"])
        assert float(rows["host_parallel_efficiency"][i]) == pytest.approx(
            expect["parallel_efficiency"])
    # exact two-rank check: LB = mean/max of per-rank active (useful +
    # offload) time = mean(0.9, 0.5) / 0.9
    assert float(rows["host_load_balance"][0]) == pytest.approx(0.7 / 0.9)


def test_merge_step_series_device_columns_nanmean_and_ragged_ranks():
    s0 = _rank_series([0.5, 0.5, 0.5], device_lb=[0.8, 0.6, 0.4])
    s1 = _rank_series([0.5, 0.5], device_lb=[0.4, float("nan")])
    job = merge_step_series({0: s0, 1: s1})
    rows = job.rows()
    assert list(rows["n_ranks"]) == [2.0, 2.0, 1.0]
    lb = rows["device_load_balance"]
    assert lb[0] == pytest.approx(0.6)   # mean(0.8, 0.4)
    assert lb[1] == pytest.approx(0.6)   # NaN rank excluded from the mean
    assert lb[2] == pytest.approx(0.4)   # only rank 0 has this step
    # rank-1 host inputs exist only for the first two steps
    assert rows["useful"] == pytest.approx([1.0, 1.0, 0.5])


def test_spool_step_series_round_trip_and_merge(tmp_path):
    spool = FileSpoolTransport(str(tmp_path))
    s0 = _rank_series([0.7, 0.7])
    s1 = _rank_series([0.3, 0.5])
    spool.submit_steps(s0, rank=0)
    spool.submit_steps(s1, rank=1)
    assert spool.step_ranks() == [0, 1]
    back = spool.collect_steps()
    _assert_rows_equal(back[0].rows(), s0.rows())
    _assert_rows_equal(back[1].rows(), s1.rows())
    job = spool.merge_steps(name="job")
    direct = merge_step_series({0: s0, 1: s1}, name="job")
    _assert_rows_equal(job.rows(), direct.rows())


def test_merge_step_series_empty_input_raises():
    with pytest.raises(ValueError, match="empty"):
        merge_step_series({})


def test_base_fields_schema_stable():
    # the spool payload's row dtype starts with the documented base fields
    s = StepSeries(capacity=1)
    names = list(s.dtype.names or ())
    assert names[: len(BASE_FIELDS)] == [n for n, _ in BASE_FIELDS]
