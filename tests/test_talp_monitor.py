"""TalpMonitor behaviour: regions, state scopes, instrumentation, online
sampling, runtime backend — with a fake clock for determinism plus one
real-JAX smoke test."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import DeviceActivity, DeviceRecord, HostState, TalpMonitor
from repro.core.backends import RuntimeBackend
from repro.core.report import render_tables, render_text, to_json, from_json


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def test_region_states_and_metrics():
    clk = FakeClock()
    mon = TalpMonitor("test", clock=clk)
    with mon.region("step"):
        clk.advance(2.0)                     # useful
        with mon.offload():
            clk.advance(1.0)
        with mon.mpi():
            clk.advance(1.0)
    res = mon.finalize()
    step = res["step"]
    assert step.elapsed == pytest.approx(4.0)
    st = step.host_states[0]
    assert st["useful"] == pytest.approx(2.0)
    assert st["offload"] == pytest.approx(1.0)
    assert st["mpi"] == pytest.approx(1.0)
    h = step.host
    assert h.parallel_efficiency == pytest.approx(0.5)
    assert h.device_offload_efficiency == pytest.approx(2.0 / 3.0)
    h.validate()
    # Global region charged too
    g = res["Global"]
    assert g.host_states[0]["offload"] == pytest.approx(1.0)


def test_nested_regions_both_charged():
    clk = FakeClock()
    mon = TalpMonitor(clock=clk)
    with mon.region("outer"):
        with mon.region("inner"):
            with mon.offload():
                clk.advance(1.0)
        clk.advance(1.0)
    res = mon.finalize()
    assert res["inner"].host_states[0]["offload"] == pytest.approx(1.0)
    assert res["outer"].host_states[0]["offload"] == pytest.approx(1.0)
    assert res["outer"].host_states[0]["useful"] == pytest.approx(1.0)
    assert res["inner"].host_states[0]["useful"] == pytest.approx(0.0)


def test_region_reopen_accumulates():
    clk = FakeClock()
    mon = TalpMonitor(clock=clk)
    for _ in range(3):
        with mon.region("iter"):
            clk.advance(1.0)
        clk.advance(0.5)  # outside region
    res = mon.finalize()
    assert res["iter"].elapsed == pytest.approx(3.0)
    assert res["Global"].elapsed == pytest.approx(4.5)


def test_region_close_mismatch_raises():
    mon = TalpMonitor()
    mon.open_region("a")
    with pytest.raises(RuntimeError):
        mon.close_region("b")


def test_nested_state_raises():
    mon = TalpMonitor()
    with pytest.raises(RuntimeError):
        with mon.offload():
            with mon.mpi():
                pass


def test_device_records_clipped_to_region_windows():
    clk = FakeClock()
    mon = TalpMonitor(clock=clk)
    with mon.region("r"):
        with mon.offload():
            clk.advance(2.0)
    # kernel half inside the region window [0, 2]
    mon.add_device_record(0, DeviceActivity.KERNEL, 1.0, 3.0)
    clk.advance(1.0)
    res = mon.finalize()
    r = res["r"]
    assert r.device_states[0]["kernel"] == pytest.approx(1.0)
    assert r.device_states[0]["idle"] == pytest.approx(1.0)
    assert res["Global"].device_states[0]["kernel"] == pytest.approx(2.0)


def test_online_sample_mid_region():
    clk = FakeClock()
    mon = TalpMonitor(clock=clk)
    mon.open_region("live")
    clk.advance(1.0)
    with mon.offload():
        clk.advance(1.0)
    snap = mon.sample("live")
    assert snap.elapsed == pytest.approx(2.0)
    assert snap.host.device_offload_efficiency == pytest.approx(0.5)
    clk.advance(2.0)
    snap2 = mon.sample("live")
    assert snap2.elapsed == pytest.approx(4.0)
    mon.close_region("live")


def test_instrument_real_jax_smoke():
    """End-to-end: wrap a jitted fn; offload + kernel record appear."""
    mon = TalpMonitor("jax")
    f = mon.instrument(jax.jit(lambda x: (x @ x).sum()), name="matmul")
    x = jnp.ones((64, 64), dtype=jnp.float32)
    with mon.region("compute"):
        out = f(x)
    assert jnp.isfinite(out)
    res = mon.finalize()
    r = res["compute"]
    assert r.host_states[0]["offload"] > 0
    assert r.device_states[0]["kernel"] > 0
    assert r.host.device_offload_efficiency < 1.0
    r.host.validate()
    r.device.validate()


def test_runtime_backend_async_overlap():
    """Async launch: device record spans launch→ready while the host is
    only charged for the blocked portion (paper use case 7 semantics)."""
    be = RuntimeBackend()
    mon = TalpMonitor("async", backend=be)
    f = jax.jit(lambda x: jnp.tanh(x @ x).sum())
    x = jnp.ones((256, 256))
    with mon.region("step"):
        h = be.launch(f, x, device=0, name="k")
        # host "useful" python work while the device computes
        acc = sum(i * i for i in range(10000))
        with mon.offload():
            be.wait(h)
    assert acc > 0
    res = mon.finalize()
    r = res["step"]
    assert r.device_states[0]["kernel"] > 0
    # kernel window ⊇ blocked window → orchestration ≥ offload fraction
    assert r.host_states[0]["useful"] > 0


def test_device_timeline_streaming_matches_one_shot():
    """Chunked/streaming ingestion must reproduce one-shot occupancy while
    keeping the pending-record buffer bounded."""
    import numpy as np
    from repro.core import DeviceTimeline

    rng = np.random.default_rng(3)
    n = 20_000
    starts = rng.uniform(0, 100.0, n)
    durs = rng.uniform(0, 0.05, n)
    kinds = [DeviceActivity.KERNEL if k else DeviceActivity.MEMORY
             for k in rng.random(n) < 0.6]

    one_shot = DeviceTimeline(compact_threshold=10**9)
    for kind, s, d in zip(kinds, starts, durs):
        one_shot.add(kind, s, s + d)

    streamed = DeviceTimeline(compact_threshold=512)
    ingested = streamed.ingest(
        (kind, s, s + d) for kind, s, d in zip(kinds, starts, durs)
    )
    assert ingested == n
    assert streamed.n_records == n
    assert len(streamed.records) < 512  # bounded pending buffer

    o1, o2 = one_shot.occupancy(), streamed.occupancy()
    assert o2.kernel == pytest.approx(o1.kernel, abs=1e-9)
    assert o2.memory == pytest.approx(o1.memory, abs=1e-9)
    assert o2.idle == pytest.approx(o1.idle, abs=1e-9)
    assert streamed.span() == one_shot.span()


def test_region_transition_inside_state_scope_raises():
    """Regression: a state scope charges its full duration at exit to the
    regions then on the stack, so opening/closing a region mid-scope
    would misattribute (or drop) time — it must raise instead."""
    clk = FakeClock()
    mon = TalpMonitor(clock=clk)
    mon.open_region("r")
    with pytest.raises(RuntimeError, match="inside host state"):
        with mon.offload():
            mon.open_region("mid")
    with pytest.raises(RuntimeError, match="inside host state"):
        with mon.offload():
            mon.close_region("r")
    # the monitor stays usable afterwards
    with mon.offload():
        clk.advance(1.0)
    mon.close_region("r")
    res = mon.finalize()
    assert res["r"].host_states[0]["offload"] == pytest.approx(1.0)


class FakeAsyncBackend:
    """Deterministic backend: the device record spans launch→ready, which
    exceeds the host-blocked (wait) window — like a real async runtime."""

    def __init__(self, clk, dispatch=3.0, blocked=2.0):
        self.clk = clk
        self.dispatch = dispatch
        self.blocked = blocked
        self._buf = []

    def launch(self, fn, *args, device=0, name="", **kwargs):
        t0 = self.clk()
        out = fn(*args, **kwargs)
        return (out, t0, device, name)

    def wait(self, handle):
        out, t0, device, name = handle
        self.clk.advance(self.blocked)
        self._buf.append(
            (device, DeviceRecord(DeviceActivity.KERNEL, t0, self.clk(), name=name))
        )
        return out

    def flush(self):
        out, self._buf = self._buf, []
        return out


def test_instrument_prefers_backend_records():
    """Regression: without a backend, instrument() synthesizes a kernel
    record spanning exactly the host-blocked window, pinning
    Orchestration Efficiency to 1. With a launch/wait backend attached,
    the record must come from the backend (launch→ready) instead — wider
    than the blocked window, and not duplicated by a synthetic record."""
    clk = FakeClock()
    be = FakeAsyncBackend(clk)
    mon = TalpMonitor(clock=clk, backend=be)

    def fake_kernel(x):
        clk.advance(3.0)  # dispatch/compile work inside launch
        return x

    f = mon.instrument(fake_kernel, name="k")
    with mon.region("r"):
        clk.advance(1.0)  # useful
        f(0)              # launch at t=1, ready at t=6, blocked [1, 6]
    res = mon.finalize()
    r = res["r"]
    # exactly one kernel record, from the backend, spanning launch→ready
    assert mon.devices[0].n_records == 1
    assert r.device_states[0]["kernel"] == pytest.approx(5.0)
    # the whole wrapped call (dispatch + wait) is host Offload
    assert r.host_states[0]["offload"] == pytest.approx(5.0)
    assert r.host_states[0]["useful"] == pytest.approx(1.0)
    # OE is NOT forced to 1: the kernel window (5s) < elapsed (6s)
    assert r.device.orchestration_efficiency == pytest.approx(5.0 / 6.0)
    r.host.validate()
    r.device.validate()


def test_instrument_forwards_reserved_kwargs_to_fn():
    """Regression: the backend path must pass the caller's kwargs to fn
    untouched, even ones that collide with launch()'s own parameter names
    (device/name/stream)."""
    clk = FakeClock()
    be = FakeAsyncBackend(clk)
    mon = TalpMonitor(clock=clk, backend=be)
    seen = {}

    def fn(x, device=None, stream=None, name=None):
        seen.update(device=device, stream=stream, name=name)
        return x

    wrapped = mon.instrument(fn, name="k")
    with mon.region("r"):
        out = wrapped(7, device="mine", stream="s0", name="n")
    assert out == 7
    assert seen == {"device": "mine", "stream": "s0", "name": "n"}


def test_report_text_and_json_roundtrip():
    clk = FakeClock()
    mon = TalpMonitor("rep", clock=clk)
    with mon.region("r"):
        clk.advance(1.0)
        with mon.offload():
            clk.advance(1.0)
    mon.add_device_record(0, DeviceActivity.KERNEL, 1.0, 2.0)
    res = mon.finalize()
    text = render_tables(res)
    assert "Parallel Efficiency" in text
    assert "Device Offload Eff." in text
    assert "Orchestration Eff." in text
    j = from_json(to_json(res))
    assert "regions" in j
    r = j["regions"]["r"]
    assert r["host_metrics"]["device_offload_efficiency"] == pytest.approx(0.5)
    assert r["device_states"]["0"]["kernel"] == pytest.approx(1.0)
    # single-region render
    assert "rank" in render_text(res["r"])
