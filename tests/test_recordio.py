"""Columnar record engine tests.

Covers the zero-object record path end to end: the ``ColumnStore``
structured buffer, column coercion/validation, the ``DeviceTimeline``
batch API, cache/regression fixes on the ingest path, equivalence of the
columnar and retained object paths (unit + property), the binary spool
payload, and the backend ``flush_arrays`` protocol.
"""

import json
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.merge import (
    FileSpoolTransport,
    load_spool_payload,
    merge_results,
    result_from_spool_bytes,
    result_to_spool_bytes,
    result_to_spool_json,
)
from repro.core.recordio import (
    KIND_KERNEL,
    KIND_MEMORY,
    RECORD_DTYPE,
    ColumnStore,
    as_record_columns,
)
from repro.core.report import to_json
from repro.core.states import (
    DeviceActivity,
    DeviceRecord,
    DeviceTimeline,
    ObjectPathTimeline,
)
from repro.core.talp import TalpMonitor


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


# ---------------------------------------------------------------------------
# ColumnStore
# ---------------------------------------------------------------------------
def test_column_store_append_and_growth():
    cs = ColumnStore(capacity=16)
    for i in range(100):  # force several doublings
        cs.append(KIND_KERNEL, float(i), float(i) + 0.5, i % 4)
    assert len(cs) == 100
    v = cs.view()
    assert v.dtype == RECORD_DTYPE
    np.testing.assert_allclose(v["start"], np.arange(100.0))
    np.testing.assert_allclose(v["end"], np.arange(100.0) + 0.5)
    assert v["stream"][5] == 1


def test_column_store_extend_take_clear():
    cs = ColumnStore()
    kinds = np.array([KIND_KERNEL, KIND_MEMORY], dtype=np.uint8)
    n = cs.extend_columns(kinds, np.array([0.0, 1.0]), np.array([0.5, 2.0]))
    assert n == 2 and len(cs) == 2
    taken = cs.take()
    assert len(cs) == 0
    assert taken["kind"].tolist() == [KIND_KERNEL, KIND_MEMORY]
    cs.append(KIND_KERNEL, 0.0, 1.0)
    cs.clear()
    assert len(cs) == 0


def test_as_record_columns_validation():
    with pytest.raises(ValueError):  # length mismatch
        as_record_columns(KIND_KERNEL, [0.0, 1.0], [0.5])
    with pytest.raises(ValueError):  # end < start
        as_record_columns(KIND_KERNEL, [1.0], [0.5])
    # DeviceActivity values coerce to codes; scalar kind broadcasts
    kinds, starts, ends, streams = as_record_columns(
        [DeviceActivity.KERNEL, DeviceActivity.MEMORY], [0, 1], [1, 2]
    )
    assert kinds.tolist() == [KIND_KERNEL, KIND_MEMORY]
    assert streams.tolist() == [0, 0]


# ---------------------------------------------------------------------------
# ingest() chunk_size regression (satellite: `chunk_size or ...` truthiness)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("cls", [DeviceTimeline, ObjectPathTimeline])
@pytest.mark.parametrize("bad", [0, -1])
def test_ingest_rejects_non_positive_chunk_size(cls, bad):
    tl = cls(device=0)
    recs = [DeviceRecord(DeviceActivity.KERNEL, 0.0, 1.0)]
    with pytest.raises(ValueError, match="chunk_size"):
        tl.ingest(recs, chunk_size=bad)
    # chunk_size=None (default) and explicit positive both work
    assert tl.ingest(recs) == 1
    assert tl.ingest(recs, chunk_size=1) == 1


# ---------------------------------------------------------------------------
# kind_intervals() caching (satellite: O(pending) re-scan per call)
# ---------------------------------------------------------------------------
def test_kind_intervals_cached_between_mutations():
    tl = DeviceTimeline(device=0, compact_threshold=1024)
    tl.add(DeviceActivity.KERNEL, 0.0, 1.0)
    tl.add(DeviceActivity.MEMORY, 0.5, 2.0)
    a = tl.kind_intervals(DeviceActivity.KERNEL)
    b = tl.kind_intervals(DeviceActivity.KERNEL)
    assert a is b  # cache hit: no pending-buffer re-scan
    tl.add(DeviceActivity.KERNEL, 3.0, 4.0)
    c = tl.kind_intervals(DeviceActivity.KERNEL)
    assert c is not a
    np.testing.assert_allclose(c, [[0.0, 1.0], [3.0, 4.0]])
    tl.compact()  # compaction invalidates the cache
    d = tl.kind_intervals(DeviceActivity.KERNEL)
    np.testing.assert_allclose(d, c)


# ---------------------------------------------------------------------------
# batch API
# ---------------------------------------------------------------------------
def test_ingest_arrays_matches_add():
    a = DeviceTimeline(device=0)
    b = DeviceTimeline(device=0)
    starts = np.array([0.0, 1.0, 0.5, 4.0])
    ends = starts + np.array([0.8, 0.2, 1.0, 0.1])
    kinds = [DeviceActivity.KERNEL, DeviceActivity.MEMORY,
             DeviceActivity.KERNEL, DeviceActivity.MEMORY]
    n = a.ingest_arrays(kinds, starts, ends)
    assert n == 4
    for k, s, e in zip(kinds, starts, ends):
        b.add(k, s, e)
    for kind in (DeviceActivity.KERNEL, DeviceActivity.MEMORY):
        np.testing.assert_array_equal(
            a.kind_intervals(kind), b.kind_intervals(kind)
        )
    assert a.span() == b.span()


def test_ingest_arrays_chunks_across_compact_threshold():
    tl = DeviceTimeline(device=0, compact_threshold=8)
    starts = np.arange(100.0)
    tl.ingest_arrays(DeviceActivity.KERNEL, starts, starts + 0.5)
    assert tl.n_records == 100
    assert tl.n_pending <= 8  # ingest slices at the compaction threshold
    assert tl.kind_intervals(DeviceActivity.KERNEL).shape == (100, 2)


# ---------------------------------------------------------------------------
# columnar vs object path — property test
# ---------------------------------------------------------------------------
@st.composite
def record_streams(draw, max_n=40, t_max=50.0):
    n = draw(st.integers(0, max_n))
    recs = []
    for _ in range(n):
        kind = draw(st.sampled_from([DeviceActivity.KERNEL,
                                     DeviceActivity.MEMORY]))
        a = draw(st.floats(0, t_max, allow_nan=False, allow_infinity=False))
        w = draw(st.floats(0, 5.0, allow_nan=False, allow_infinity=False))
        stream = draw(st.integers(0, 3))
        recs.append((kind, a, a + w, stream))
    return recs


@settings(max_examples=60, deadline=None)
@given(recs=record_streams(), threshold=st.integers(1, 16),
       interleave=st.booleans())
def test_columnar_equals_object_path(recs, threshold, interleave):
    """The columnar engine and the retained object-path reference produce
    identical compacted intervals and spans for arbitrary streams,
    including interleaved compact() calls and kinds with no records."""
    col = DeviceTimeline(device=0, compact_threshold=threshold)
    obj = ObjectPathTimeline(device=0, compact_threshold=threshold)
    for i, (kind, s, e, stream) in enumerate(recs):
        col.add(kind, s, e, stream=stream)
        obj.add(kind, s, e, stream=stream)
        if interleave and i % 3 == 0:
            col.compact()
            obj.compact()
    assert col.n_records == obj.n_records == len(recs)
    for kind in (DeviceActivity.KERNEL, DeviceActivity.MEMORY):
        np.testing.assert_array_equal(
            col.kind_intervals(kind), obj.kind_intervals(kind),
            err_msg=f"kind={kind}",
        )
    assert col.span() == obj.span()


@settings(max_examples=25, deadline=None)
@given(recs=record_streams(max_n=25))
def test_columnar_region_metrics_equal_object_path(recs):
    """Per-region metric trees are bit-identical whether device activity
    flows through the columnar engine or the object-path reference."""

    def run(timeline_cls):
        clk = FakeClock()
        mon = TalpMonitor("prop", clock=clk)
        mon.devices[0] = timeline_cls(device=0, compact_threshold=7)
        with mon.region("step"):
            clk.advance(2.0)
            with mon.offload():
                clk.advance(3.0)
        for kind, s, e, stream in recs:
            mon.devices[0].add(kind, s, e, stream=stream)
        return mon.finalize()

    a, b = run(DeviceTimeline), run(ObjectPathTimeline)
    assert to_json(a) == to_json(b)


# ---------------------------------------------------------------------------
# binary spool payload
# ---------------------------------------------------------------------------
def _result_with_devices(rank=0):
    clk = FakeClock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    with mon.region("step"):
        clk.advance(1.0)
        with mon.offload():
            clk.advance(2.0)
    mon.add_device_record(0, DeviceActivity.KERNEL, 0.0, 1.5)
    mon.add_device_record(0, DeviceActivity.MEMORY, 1.0, 2.5)
    mon.add_device_record(1, DeviceActivity.KERNEL, 0.5, 2.0)
    return mon.finalize(), mon.devices


def test_spool_bytes_round_trip_with_timelines():
    result, devices = _result_with_devices()
    blob = result_to_spool_bytes(result, timelines=devices)
    back, timelines = result_from_spool_bytes(blob)
    assert to_json(back) == to_json(result)
    assert sorted(timelines) == [0, 1]
    for dev, tl in timelines.items():
        for kind in (DeviceActivity.KERNEL, DeviceActivity.MEMORY):
            np.testing.assert_array_equal(
                tl.kind_intervals(kind), devices[dev].kind_intervals(kind)
            )
        assert tl.span() == devices[dev].span()


def test_spool_bytes_rejects_future_version():
    result, _ = _result_with_devices()
    blob = result_to_spool_bytes(result)
    # Rewrite the header with a bumped version field.
    import io

    with np.load(io.BytesIO(blob)) as z:
        header = json.loads(bytes(z["header"].tobytes()).decode("utf-8"))
        arrays = {k: z[k] for k in z.files if k != "header"}
    header["version"] = 99
    raw = json.dumps(header).encode("utf-8")
    buf = io.BytesIO()
    np.savez(buf, header=np.frombuffer(raw, dtype=np.uint8), **arrays)
    with pytest.raises(ValueError, match="version"):
        result_from_spool_bytes(buf.getvalue())


def test_binary_and_json_spools_merge_identically(tmp_path):
    bdir, jdir = tmp_path / "bin", tmp_path / "json"
    bdir.mkdir(), jdir.mkdir()
    bspool = FileSpoolTransport(str(bdir), world_size=3, payload="binary")
    jspool = FileSpoolTransport(str(jdir), world_size=3, payload="json")
    per_rank = []
    for r in range(3):
        res, devs = _result_with_devices(rank=r)
        per_rank.append(res)
        bspool.submit(res, rank=r, timelines=devs)
        jspool.submit(res, rank=r)  # legacy JSON, no timeline columns
    assert all(p.suffix == ".npz" for p in bdir.glob("talp_rank*.*"))
    assert all(p.suffix == ".json" for p in jdir.glob("talp_rank*.*"))
    merged_b = merge_results(bspool.collect())
    merged_j = merge_results(jspool.collect())
    ref = merge_results(per_rank)
    for merged in (merged_b, merged_j):
        assert to_json(merged) == to_json(ref)
    # Binary spools also carry the raw device timelines.
    tls = bspool.collect_timelines()
    assert sorted(tls) == [0, 1, 2] and sorted(tls[0]) == [0, 1]


def test_load_spool_payload_legacy_json(tmp_path):
    """A pre-binary spool file (plain JSON, no device_timelines key) still
    loads and merges unchanged."""
    result, _ = _result_with_devices()
    path = tmp_path / "talp_rank00000.json"
    obj = json.loads(to_json(result))  # exactly what the legacy transport wrote
    path.write_text(json.dumps(obj))
    back, timelines = load_spool_payload(str(path))
    assert timelines == {}
    assert to_json(back) == to_json(result)
    # and the new writer without timelines is byte-compatible with legacy
    assert json.loads(result_to_spool_json(result)) == obj


def test_merge_cli_reads_binary_spool(tmp_path):
    spool = FileSpoolTransport(str(tmp_path), world_size=2, payload="binary")
    for r in range(2):
        res, devs = _result_with_devices(rank=r)
        spool.submit(res, rank=r, timelines=devs)
    out = tmp_path / "job.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.core.merge", str(tmp_path),
         "--json-out", str(out)],
        capture_output=True, text=True,
    )
    assert proc.returncode == 0, proc.stderr
    job = json.loads(out.read_text())
    assert "step" in job.get("regions", job)


# ---------------------------------------------------------------------------
# backend flush_arrays protocol
# ---------------------------------------------------------------------------
def test_monitor_prefers_columnar_backend_flush():
    from repro.core.backends.base import ColumnarActivityBackend
    from repro.core.backends.synthetic import SyntheticBackend

    be = SyntheticBackend()
    assert isinstance(be, ColumnarActivityBackend)
    clk = FakeClock()
    mon = TalpMonitor(clock=clk, backend=be)
    with mon.region("step"):
        clk.advance(1.0)
    starts = np.array([0.0, 0.3])
    be.push_arrays(0, np.array([KIND_KERNEL, KIND_MEMORY], dtype=np.uint8),
                   starts, starts + 0.25)
    result = mon.finalize()
    tl = mon.devices[0]
    assert tl.n_records == 2
    np.testing.assert_allclose(
        tl.kind_intervals(DeviceActivity.KERNEL), [[0.0, 0.25]]
    )
    assert "step" in result.regions
