"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles, executed with interpret=True (kernel bodies run on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ops import attention
from repro.kernels.flash_attention.ref import attention_reference
from repro.kernels.ssd.kernel import ssd_pallas
from repro.kernels.ssd.ref import ssd_reference, ssd_sequential


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
ATTN_SWEEP = [
    # (B, S, T, H, K, D, window, softcap, dtype)
    (1, 128, 128, 4, 4, 64, None, None, jnp.float32),     # MHA
    (2, 256, 256, 4, 2, 64, None, None, jnp.float32),     # GQA 2:1
    (1, 256, 256, 8, 2, 32, None, None, jnp.float32),     # GQA 4:1
    (1, 256, 256, 4, 1, 64, None, None, jnp.float32),     # MQA
    (1, 256, 256, 4, 2, 64, 64, None, jnp.float32),       # sliding window
    (1, 256, 256, 4, 2, 64, None, 50.0, jnp.float32),     # softcap (gemma2)
    (1, 256, 256, 4, 2, 64, 128, 30.0, jnp.float32),      # window+softcap
    (1, 384, 384, 2, 2, 128, None, None, jnp.float32),    # D=128, S%block!=pow2
    (2, 128, 128, 4, 2, 64, None, None, jnp.bfloat16),    # bf16
    (1, 256, 256, 4, 2, 64, 64, 50.0, jnp.bfloat16),      # bf16 + features
]


@pytest.mark.parametrize(
    "b,s,t,h,k,d,window,softcap,dtype", ATTN_SWEEP,
    ids=[f"attn{i}" for i in range(len(ATTN_SWEEP))],
)
def test_flash_attention_vs_ref(b, s, t, h, k, d, window, softcap, dtype):
    keys = jax.random.split(jax.random.PRNGKey(42), 3)
    q = jax.random.normal(keys[0], (b, s, h, d)).astype(dtype)
    kk = jax.random.normal(keys[1], (b, t, k, d)).astype(dtype)
    vv = jax.random.normal(keys[2], (b, t, k, d)).astype(dtype)
    out = flash_attention(q, kk, vv, causal=True, window=window,
                          softcap=softcap, interpret=True)
    ref = attention_reference(q, kk, vv, causal=True, window=window,
                              softcap=softcap)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype),
    )


def test_flash_attention_block_sizes():
    """Block-shape sweep: result invariant to tiling choices."""
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(keys[0], (1, 512, 4, 64))
    k = jax.random.normal(keys[1], (1, 512, 2, 64))
    v = jax.random.normal(keys[2], (1, 512, 2, 64))
    ref = attention_reference(q, k, v, causal=True)
    for bq, bk in [(64, 64), (128, 256), (256, 128), (512, 512)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_kv=bk,
                              interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5,
                                   err_msg=f"bq={bq} bk={bk}")


def test_xla_path_matches_ref():
    """The model's chunked-attention XLA path equals the oracle too."""
    keys = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(keys[0], (2, 256, 4, 64))
    k = jax.random.normal(keys[1], (2, 256, 2, 64))
    v = jax.random.normal(keys[2], (2, 256, 2, 64))
    for window, cap in [(None, None), (64, None), (None, 50.0)]:
        out = attention(q, k, v, window=window, softcap=cap, impl="xla",
                        kv_chunk=64)
        ref = attention_reference(q, k, v, causal=True, window=window,
                                  softcap=cap)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
SSD_SWEEP = [
    # (B, L, H, P, G, N, chunk, dtype)
    (1, 64, 2, 16, 1, 16, 16, jnp.float32),
    (2, 128, 4, 16, 2, 32, 32, jnp.float32),
    (1, 128, 4, 64, 1, 64, 64, jnp.float32),    # mamba2-like head dims
    (1, 256, 8, 32, 1, 16, 128, jnp.float32),   # long chunk
    (2, 128, 4, 16, 4, 32, 32, jnp.float32),    # G == H
    (1, 128, 4, 16, 2, 32, 32, jnp.bfloat16),   # bf16
]


@pytest.mark.parametrize(
    "b,l,h,p,g,n,chunk,dtype", SSD_SWEEP,
    ids=[f"ssd{i}" for i in range(len(SSD_SWEEP))],
)
def test_ssd_pallas_vs_ref(b, l, h, p, g, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = jax.random.normal(ks[0], (b, l, h, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h))).astype(jnp.float32)
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, g, n)).astype(dtype)
    cm = jax.random.normal(ks[4], (b, l, g, n)).astype(dtype)
    d = jnp.full((h,), 0.5)
    out = ssd_pallas(x, dt, a, bm, cm, chunk=chunk, d_skip=d, interpret=True)
    ref = ssd_reference(x, dt, a, bm, cm, chunk=chunk, d_skip=d)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        **_tol(dtype),
    )


def test_ssd_chunked_ref_vs_sequential():
    """The chunked oracle equals the token-by-token recurrence (and is
    chunk-size invariant)."""
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    b, l, h, p, g, n = 2, 96, 4, 8, 2, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    ref = ssd_sequential(x, dt, a, bm, cm)
    for chunk in (16, 32, 48, 96):
        out = ssd_reference(x, dt, a, bm, cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=5e-4, atol=5e-4,
                                   err_msg=f"chunk={chunk}")
    # non-divisible chunk takes the padded path
    out = ssd_reference(x, dt, a, bm, cm, chunk=40)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=5e-4, atol=5e-4)


def test_ssd_state_carry_across_calls():
    """final state from one call seeds the next (prefill→decode contract)."""
    ks = jax.random.split(jax.random.PRNGKey(9), 5)
    b, l, h, p, g, n = 1, 64, 2, 8, 1, 16
    x = jax.random.normal(ks[0], (b, l, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, l, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bm = jax.random.normal(ks[3], (b, l, g, n))
    cm = jax.random.normal(ks[4], (b, l, g, n))
    full, s_full = ssd_reference(x, dt, a, bm, cm, chunk=16,
                                 return_final_state=True)
    half = l // 2
    y1, s1 = ssd_reference(x[:, :half], dt[:, :half], a, bm[:, :half],
                           cm[:, :half], chunk=16, return_final_state=True)
    y2, s2 = ssd_reference(x[:, half:], dt[:, half:], a, bm[:, half:],
                           cm[:, half:], chunk=16, initial_state=s1,
                           return_final_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], axis=1)),
                               np.asarray(full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-4, atol=1e-4)
