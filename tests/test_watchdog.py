"""Efficiency watchdog: drift detection with hierarchy attribution,
hysteresis, the anomaly-event schema, the JSONL stream, and the
synthetic end-to-end scenario CI smokes."""

import json
import math

import pytest

from repro.core.telemetry import watchdog as wdm
from repro.core.telemetry.watchdog import (
    EfficiencyWatchdog,
    load_anomaly_jsonl,
    synthetic_drift_scenario,
    validate_anomaly_events,
)


def _feed(wd, values_by_step, region="step"):
    """Drive the watchdog with one metric row per step."""
    out = []
    for i, values in enumerate(values_by_step):
        out.extend(wd.observe(region=region, step=i, t=float(i), values=values))
    return out


def _const_rows(col, value, n, **extra):
    return [{col: value, **extra} for _ in range(n)]


# ---------------------------------------------------------------------------
# detection semantics on hand-built streams
# ---------------------------------------------------------------------------
def test_no_events_during_warmup():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    rows = _const_rows("host_parallel_efficiency", 0.9, 4)
    rows.append({"host_parallel_efficiency": 0.1})   # huge jump, still warmup
    assert _feed(wd, rows) == []
    assert wd.events == []


def test_persistent_shift_emits_exactly_one_event():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    rows = (_const_rows("host_parallel_efficiency", 0.9, 20)
            + _const_rows("host_parallel_efficiency", 0.5, 20))
    events = _feed(wd, rows)
    assert len(events) == 1
    ev = events[0]
    assert ev.step == 20
    assert ev.region == "step"
    assert ev.hierarchy == "host"
    assert ev.metric == "parallel_efficiency"
    assert ev.direction == "drop"
    assert ev.z < 0
    assert wd.firing() == [
        {"region": "step", "metric": "host_parallel_efficiency"}
    ]
    assert wd.summary()["n_events"] == 1


def test_hysteresis_clears_then_refires():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    col = "host_parallel_efficiency"
    rows = (_const_rows(col, 0.9, 20)     # baseline
            + _const_rows(col, 0.5, 5)    # shift -> 1 event, baseline frozen
            + _const_rows(col, 0.9, 10)   # recovery -> detector clears
            + _const_rows(col, 0.5, 5))   # second shift -> second event
    events = _feed(wd, rows)
    assert len(events) == 2
    assert events[0].step == 20
    assert events[1].step >= 35
    assert wd.firing()                     # second shift still firing at end


def test_rise_direction_detected():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    rows = (_const_rows("host_parallel_efficiency", 0.5, 20)
            + _const_rows("host_parallel_efficiency", 0.9, 5))
    events = _feed(wd, rows)
    assert len(events) == 1
    assert events[0].direction == "rise" and events[0].z > 0


def test_cusum_catches_slow_drift_below_z_threshold():
    # each step moves by a fraction of sigma (z_fire would never trip on
    # its own with a generous min_sigma), but the drift accumulates
    wd = EfficiencyWatchdog(
        metrics=("host_parallel_efficiency",),
        min_sigma=0.05, z_fire=50.0, cusum_k=0.25, cusum_h=8.0,
    )
    rows = _const_rows("host_parallel_efficiency", 0.9, 20)
    rows += [{"host_parallel_efficiency": 0.9 - 0.02 * i} for i in range(40)]
    events = _feed(wd, rows)
    assert len(events) >= 1
    assert events[0].detector == "cusum"
    assert events[0].direction == "drop"


def test_nan_and_missing_values_skipped():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    rows = _const_rows("host_parallel_efficiency", 0.9, 20)
    rows.append({"host_parallel_efficiency": math.nan})
    rows.append({})                        # metric absent this step
    rows += _const_rows("host_parallel_efficiency", 0.9, 5)
    assert _feed(wd, rows) == []


def test_unwatched_columns_get_baselines_but_never_fire():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    rows = [
        {"host_parallel_efficiency": 0.9, "host_load_balance": 0.9}
        for _ in range(20)
    ]
    rows += [
        {"host_parallel_efficiency": 0.9, "host_load_balance": 0.2}
        for _ in range(10)
    ]
    assert _feed(wd, rows) == []           # only the watched column fires
    # but the unwatched column's baseline exists (it feeds attribution)
    assert ("step", "host_load_balance") in wd._baselines


def test_attribution_names_largest_multiplicative_mover():
    # parallel_efficiency and load_balance drop together while the other
    # multiplicative children stay flat: the attribution path must descend
    # parallel_efficiency -> load_balance
    wd = EfficiencyWatchdog(metrics=("device_parallel_efficiency",))
    flat = {
        "device_parallel_efficiency": 0.9,
        "device_load_balance": 0.95,
        "device_communication_efficiency": 0.98,
        "device_orchestration_efficiency": 0.97,
    }
    degraded = dict(flat)
    degraded["device_parallel_efficiency"] = 0.4
    degraded["device_load_balance"] = 0.42
    events = _feed(wd, [flat] * 20 + [degraded] * 5)
    assert len(events) == 1
    attr = events[0].attribution
    assert attr and attr[0]["metric"] == "device_load_balance"
    assert attr[0]["dlog"] < 0


# ---------------------------------------------------------------------------
# schema checker + JSONL stream
# ---------------------------------------------------------------------------
def _one_real_event():
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",))
    events = _feed(wd, _const_rows("host_parallel_efficiency", 0.9, 20)
                   + _const_rows("host_parallel_efficiency", 0.5, 2))
    assert len(events) == 1
    return events[0].as_dict()


def test_validate_accepts_real_events():
    assert validate_anomaly_events([_one_real_event()]) == 1
    assert validate_anomaly_events([]) == 0


@pytest.mark.parametrize(
    "mutate, match",
    [
        (lambda d: d.update(kind="oops"), "kind"),
        (lambda d: d.update(step="3"), "step"),
        (lambda d: d.update(step=True), "step"),
        (lambda d: d.update(region=""), "region"),
        (lambda d: d.pop("metric"), "metric"),
        (lambda d: d.update(z=float("inf")), "finite"),
        (lambda d: d.update(baseline_std=-1.0), ">= 0"),
        (lambda d: d.update(detector="psychic"), "detector"),
        (lambda d: d.update(direction="sideways"), "direction"),
        (lambda d: d.update(attribution="nope"), "attribution"),
        (lambda d: d.update(attribution=[{"metric": ""}]), "attribution"),
        (lambda d: d.update(
            attribution=[{"metric": "x", "observed": "y",
                          "baseline": 0.1, "dlog": 0.0}]), "attribution"),
    ],
)
def test_validate_rejects_malformed(mutate, match):
    ev = _one_real_event()
    mutate(ev)
    with pytest.raises(ValueError, match=match):
        validate_anomaly_events([ev])


def test_jsonl_stream_and_loader_round_trip(tmp_path):
    path = str(tmp_path / "anomalies.jsonl")
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",), jsonl=path)
    _feed(wd, _const_rows("host_parallel_efficiency", 0.9, 20)
          + _const_rows("host_parallel_efficiency", 0.5, 2))
    wd.close()
    loaded = load_anomaly_jsonl(path)
    assert loaded == [e.as_dict() for e in wd.events]
    assert validate_anomaly_events(loaded) == len(wd.events) == 1


def test_jsonl_filelike_sink_not_closed_by_watchdog(tmp_path):
    import io

    buf = io.StringIO()
    wd = EfficiencyWatchdog(metrics=("host_parallel_efficiency",), jsonl=buf)
    _feed(wd, _const_rows("host_parallel_efficiency", 0.9, 20)
          + _const_rows("host_parallel_efficiency", 0.5, 2))
    wd.close()                             # caller owns the file-like sink
    lines = [json.loads(l) for l in buf.getvalue().splitlines()]
    assert len(lines) == 1 and lines[0]["kind"] == "anomaly"


# ---------------------------------------------------------------------------
# the synthetic end-to-end scenario (what CI smokes)
# ---------------------------------------------------------------------------
def test_drift_scenario_detects_injection_with_attribution():
    sc = synthetic_drift_scenario(steps=60)
    wd = sc["watchdog"]
    assert wd.events, "injected drift must be detected"
    assert validate_anomaly_events([e.as_dict() for e in wd.events])
    # every event is at/after the injection point, on a device metric
    assert all(e.step >= sc["inject_step"] for e in wd.events)
    assert all(e.hierarchy == "device" for e in wd.events)
    assert all(e.direction == "drop" for e in wd.events)
    metrics = {e.metric for e in wd.events}
    assert "load_balance" in metrics
    # the parallel_efficiency event is attributed to load_balance, not to
    # the (unchanged) orchestration efficiency
    pe = [e for e in wd.events if e.metric == "parallel_efficiency"]
    assert pe and pe[0].attribution
    assert pe[0].attribution[0]["metric"] == "device_load_balance"
    # the series recorded every step (finalize adds the Global close row)
    series = sc["recorder"].series
    assert len(series.column("step", region="step")) == 60
    assert sc["result"].regions["step"].elapsed > 0


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_steady_scenario_stays_silent(seed):
    sc = synthetic_drift_scenario(steps=60, inject=False, seed=seed)
    assert sc["watchdog"].events == []
    assert sc["inject_step"] is None


def test_scenario_cli_expectations(tmp_path, capsys):
    log = str(tmp_path / "anoms.jsonl")
    assert wdm.main(["--steps", "60", "--anomaly-log", log,
                     "--expect-anomaly"]) == 0
    assert wdm.main(["--steps", "60", "--steady", "--expect-clean"]) == 0
    assert wdm.main(["--validate", log]) == 0
    assert wdm.main(["--steps", "60", "--steady", "--expect-anomaly"]) == 1
    bad = tmp_path / "bad.jsonl"
    bad.write_text('{"kind": "nonsense"}\n')
    assert wdm.main(["--validate", str(bad)]) == 1
    out = capsys.readouterr().out
    assert "INVALID" in out
