"""Paper §5.2 validation: the application emulators reproduce the
structure of Tables 1–3 (metric levels at 1 node; scaling trends to 8)."""

import pytest

from repro.appsim import node_scan


@pytest.fixture(scope="module")
def scans():
    return {app: node_scan(app) for app in ("sod2d", "fall3d", "xshells")}


# ---------------------------------------------------------------------------
# Table 1 — SOD2D
# ---------------------------------------------------------------------------
def test_sod2d_table1(scans):
    s = scans["sod2d"]
    # n=1 column (paper: MPI PE .94, CE .95, LB 1.0, DOE .06, dev PE .87)
    a1 = s[1]
    assert a1.host.mpi_parallel_efficiency == pytest.approx(0.94, abs=0.02)
    assert a1.host.communication_efficiency == pytest.approx(0.95, abs=0.02)
    assert a1.host.load_balance == pytest.approx(1.0, abs=0.02)
    assert a1.host.device_offload_efficiency == pytest.approx(0.06, abs=0.01)
    assert a1.device.parallel_efficiency == pytest.approx(0.87, abs=0.03)
    # trends to 8 nodes: CE and Orchestration degrade, DOE flat, LB high
    a8 = s[8]
    assert a8.host.communication_efficiency == pytest.approx(0.68, abs=0.04)
    assert a8.device.orchestration_efficiency == pytest.approx(0.60, abs=0.06)
    assert a8.host.device_offload_efficiency == pytest.approx(0.06, abs=0.01)
    assert a8.device.load_balance > 0.95


def test_sod2d_monotonic_degradation(scans):
    s = scans["sod2d"]
    ce = [s[n].host.communication_efficiency for n in (1, 2, 4, 8)]
    oe = [s[n].device.orchestration_efficiency for n in (1, 2, 4, 8)]
    assert ce == sorted(ce, reverse=True)
    assert oe == sorted(oe, reverse=True)


# ---------------------------------------------------------------------------
# Table 2 — FALL3D
# ---------------------------------------------------------------------------
def test_fall3d_table2(scans):
    s = scans["fall3d"]
    a1, a8 = s[1], s[8]
    # n=1 column (paper: LB .52, DOE .59, dev CE .78, Orch .19)
    assert a1.host.load_balance == pytest.approx(0.52, abs=0.04)
    assert a1.host.device_offload_efficiency == pytest.approx(0.59, abs=0.05)
    assert a1.device.communication_efficiency == pytest.approx(0.78, abs=0.02)
    assert a1.device.orchestration_efficiency == pytest.approx(0.19, abs=0.04)
    # scaling: load balance collapses (init does not scale), orch → ~0.04
    assert a8.host.load_balance == pytest.approx(0.12, abs=0.04)
    assert a8.device.orchestration_efficiency == pytest.approx(0.04, abs=0.02)
    # device LB stays high throughout (paper: .96-.98)
    for n in (1, 2, 4, 8):
        assert s[n].device.load_balance > 0.95


# ---------------------------------------------------------------------------
# Table 3 — XSHELLS
# ---------------------------------------------------------------------------
def test_xshells_table3(scans):
    s = scans["xshells"]
    a1, a8 = s[1], s[8]
    # n=1 (paper: DOE .40, dev CE .98, LB 1.0, Orch .54)
    assert a1.host.device_offload_efficiency == pytest.approx(0.40, abs=0.03)
    assert a1.device.communication_efficiency == pytest.approx(0.98, abs=0.01)
    assert a1.device.load_balance == pytest.approx(1.0, abs=0.01)
    assert a1.device.orchestration_efficiency == pytest.approx(0.54, abs=0.05)
    # paper trends: host CE drops hard; DOE *rises*; orchestration falls
    assert a8.host.communication_efficiency < 0.65
    assert a8.host.device_offload_efficiency > a1.host.device_offload_efficiency
    assert a8.device.orchestration_efficiency < 0.35
    # load balance stays ~1.0 at every scale (paper: 0.93-1.0)
    for n in (1, 2, 4, 8):
        assert s[n].host.load_balance > 0.93


def test_all_scans_multiplicative(scans):
    for scan in scans.values():
        for a in scan.values():
            a.validate(tol=1e-6)
