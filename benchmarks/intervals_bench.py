"""Interval-engine micro-benchmark: vectorized ``subtract``/``intersect``
vs the scalar loop references, on 10^6 random intervals.

Prints ``name,us_per_call,derived`` CSV rows (same convention as run.py)
and verifies that the vectorized outputs are *identical* (bit-for-bit) to
the loop outputs before timing. Exits non-zero if the speedup target is
missed, so CI can gate on it.

Usage:
  PYTHONPATH=src python benchmarks/intervals_bench.py [--n 1000000]
"""

from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.core import intervals as iv


def _bench(fn, n_iter: int = 3, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6  # us


def _row(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def random_flat(n: int, rng: np.random.Generator, t_max: float) -> np.ndarray:
    starts = np.sort(rng.uniform(0, t_max, n))
    ends = starts + rng.uniform(0, 0.4 * t_max / n * 2, n)
    return iv.flatten(np.stack([starts, ends], axis=1))


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1_000_000,
                    help="random intervals per operand")
    ap.add_argument("--target-speedup", type=float, default=10.0)
    ap.add_argument("--loop-iters", type=int, default=1,
                    help="timing iterations for the slow loop reference")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    t_max = float(args.n)
    a = random_flat(args.n, rng, t_max)
    b = random_flat(args.n, rng, t_max)
    _row("intervals_operands", 0.0, f"|a|={len(a)} |b|={len(b)} flat")

    ok = True
    for name, vec, loop in (
        ("subtract", iv.subtract, iv._subtract_loop),
        ("intersect", iv.intersect, iv._intersect_loop),
    ):
        out_vec = vec(a, b)
        out_loop = loop(a, b)
        identical = out_vec.shape == out_loop.shape and bool(
            np.array_equal(out_vec, out_loop)
        )
        us_vec = _bench(lambda: vec(a, b))
        us_loop = _bench(lambda: loop(a, b), n_iter=args.loop_iters)
        speedup = us_loop / us_vec
        _row(f"{name}_vectorized_1e6", us_vec,
             f"speedup={speedup:.1f}x identical={identical} out={len(out_vec)}")
        _row(f"{name}_loop_1e6", us_loop, "scalar reference")
        ok = ok and identical and speedup >= args.target_speedup

    # streaming flatten: one million records through a chunked timeline
    from repro.core.states import DeviceActivity, DeviceTimeline

    starts = rng.uniform(0, t_max, args.n)
    durs = rng.uniform(0, 0.1, args.n)
    kinds = rng.random(args.n) < 0.7

    def stream():
        tl = DeviceTimeline(compact_threshold=65536)
        tl.ingest(
            (DeviceActivity.KERNEL if k else DeviceActivity.MEMORY, s, s + d)
            for k, s, d in zip(kinds, starts, durs)
        )
        return tl.occupancy()

    us = _bench(stream, n_iter=1, warmup=0)
    _row("timeline_stream_1e6", us, f"{args.n / (us / 1e6) / 1e6:.2f}M rec/s")

    if not ok:
        print(f"FAIL: speedup < {args.target_speedup}x or outputs differ",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    print("name,us_per_call,derived")
    sys.exit(main())
