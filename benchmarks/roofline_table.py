"""Render EXPERIMENTS.md §Roofline table from dry-run JSON artifacts.

Usage: PYTHONPATH=src python -m benchmarks.roofline_table [dir] [--md]
"""

from __future__ import annotations

import json
import os
import sys


def load_cells(d: str):
    cells = []
    for name in sorted(os.listdir(d)):
        if not name.endswith(".json"):
            continue
        with open(os.path.join(d, name)) as f:
            cells.append(json.load(f))
    return cells


def fmt_row(c, md=False):
    sep = " | " if md else "  "
    ms = lambda s: f"{s*1e3:9.2f}"
    return sep.join([
        f"{c['arch']:<24s}", f"{c['shape']:<12s}",
        ms(c["compute_s"]), ms(c["memory_s"]), ms(c["collective_s"]),
        f"{c['dominant']:<10s}",
        f"{c['model_flops']*c['chips']:.2e}",
        f"{c['useful_flop_ratio']:6.3f}",
        f"{c['roofline_fraction']:6.3f}",
        f"{c.get('bound_fraction', 0.0):6.3f}",
    ])


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_single"
    md = "--md" in sys.argv
    cells = [c for c in load_cells(d) if c.get("status") == "ok"]
    cells.sort(key=lambda c: (c["arch"], c["shape"]))
    hdr = ["arch", "shape", "compute_ms", "memory_ms", "coll_ms",
           "dominant", "MODEL_FLOPS", "useful", "frac", "bound"]
    if md:
        print("| " + " | ".join(hdr) + " |")
        print("|" + "---|" * len(hdr))
        for c in cells:
            print("| " + fmt_row(c, md=True) + " |")
    else:
        print("  ".join(hdr))
        for c in cells:
            print(fmt_row(c))
    # hillclimb candidates
    by_frac = sorted(cells, key=lambda c: c["roofline_fraction"])
    coll = sorted(cells, key=lambda c: -c["collective_s"] /
                  max(1e-12, c["compute_s"] + c["memory_s"] + c["collective_s"]))
    print(f"\nworst fraction: {by_frac[0]['arch']}/{by_frac[0]['shape']} "
          f"({by_frac[0]['roofline_fraction']:.4f})", file=sys.stderr)
    print(f"most collective-bound: {coll[0]['arch']}/{coll[0]['shape']} "
          f"(coll share {coll[0]['collective_s']/max(1e-12, coll[0]['compute_s']+coll[0]['memory_s']+coll[0]['collective_s']):.3f})",
          file=sys.stderr)


if __name__ == "__main__":
    main()
