"""Multi-rank merge micro-benchmark: cost of central aggregation as the
job scales in ranks, the file-spool transport round trip, and the
incremental-sampling speedup (cached flattened timelines vs re-flattening
the whole record history on every ``sample()``).

Prints ``name,us_per_call,derived`` CSV rows (same convention as run.py).
Exits nonzero if the incremental sample path is slower than
``--sample-target-speedup``× the non-incremental baseline.

Usage:
  PYTHONPATH=src python benchmarks/merge_bench.py [--ranks 64] \
      [--sample-records 100000] [--sample-target-speedup 5]
"""

from __future__ import annotations

import argparse
import sys
import tempfile
import time

from repro.core import DeviceActivity, TalpMonitor
from repro.core.merge import FileSpoolTransport, merge_results, merge_samples


def _bench(fn, n_iter: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6  # us


def _row(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def simulate_rank(rank: int, n_regions: int = 8) -> object:
    """One synthetic rank result with several regions + device records."""
    clk = _Clock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    for i in range(n_regions):
        with mon.region(f"region{i}"):
            clk.advance(1.0 + 0.1 * ((rank + i) % 5))
            with mon.offload():
                clk.advance(0.5)
    t = 0.0
    for i in range(64):  # 64 activity records per rank
        mon.add_device_record(0, DeviceActivity.KERNEL, t, t + 0.05)
        mon.add_device_record(0, DeviceActivity.MEMORY, t + 0.05, t + 0.06)
        t += 0.1
    return mon.finalize()


def _sampled_monitor(n_records: int, incremental: bool) -> TalpMonitor:
    """Monitor with an open region and a long device-record history —
    the online-sampling scenario (records keep arriving, sample() is
    called periodically)."""
    clk = _Clock()
    mon = TalpMonitor("sampled", clock=clk, incremental=incremental)
    mon.open_region("loop")
    t = 0.0
    for i in range(n_records):
        kind = DeviceActivity.KERNEL if i % 4 else DeviceActivity.MEMORY
        # heavy overlap: flattened arrays stay small, so the cost under
        # measurement is the per-sample record folding, not the metric math
        mon.add_device_record(0, kind, t, t + 0.003)
        t += 0.001
    clk.advance(t + 1.0)
    return mon


def bench_incremental_sample(n_records: int, target_speedup: float) -> bool:
    """sample() on an n_records timeline: incremental (cached flattened
    timelines, fold only new records) vs full re-flatten baseline."""
    base_mon = _sampled_monitor(n_records, incremental=False)
    inc_mon = _sampled_monitor(n_records, incremental=True)

    us_base = _bench(lambda: base_mon.sample("loop"), n_iter=3)
    us_inc = _bench(lambda: inc_mon.sample("loop"), n_iter=3)
    speedup = us_base / us_inc if us_inc > 0 else float("inf")
    _row(f"sample_full_reflatten_{n_records}", us_base, "baseline")
    _row(f"sample_incremental_{n_records}", us_inc,
         f"{speedup:.1f}x vs baseline (target {target_speedup:.1f}x)")

    # consistency: both paths must report identical metrics
    b, i = base_mon.sample("loop"), inc_mon.sample("loop")
    assert b.host.parallel_efficiency == i.host.parallel_efficiency
    assert b.device.parallel_efficiency == i.device.parallel_efficiency

    # informational: cost of a sample right after new records arrive
    # (cache miss -> fold the pending chunk into the compacted arrays)
    def arrival_sample():
        mon = inc_mon
        now = mon.clock()
        for j in range(64):
            mon.add_device_record(0, DeviceActivity.KERNEL,
                                  now + j * 0.001, now + j * 0.001 + 0.003)
        return mon.sample("loop")

    us_arrival = _bench(arrival_sample, n_iter=3)
    _row(f"sample_incremental_arrival_{n_records}", us_arrival,
         "64 new records per sample")
    return speedup >= target_speedup


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=64)
    ap.add_argument("--sample-records", type=int, default=100_000)
    ap.add_argument("--sample-target-speedup", type=float, default=5.0)
    args = ap.parse_args()

    for n in (4, 16, args.ranks):
        results = [simulate_rank(r) for r in range(n)]
        us = _bench(lambda: merge_results(results, name="job"))
        job = merge_results(results, name="job")
        pe = job["region0"].host.parallel_efficiency
        _row(f"merge_{n}_ranks", us, f"{n / (us / 1e6):.0f} ranks/s PE={pe:.3f}")
        for region in job.regions.values():
            if region.host:
                region.host.validate()
            if region.device:
                region.device.validate()

    # spool transport round trip (serialize + atomic publish + reload + merge)
    results = [simulate_rank(r) for r in range(args.ranks)]
    with tempfile.TemporaryDirectory() as d:
        spool = FileSpoolTransport(d, world_size=args.ranks)

        def roundtrip():
            for r, res in enumerate(results):
                spool.submit(res, rank=r)
            return spool.merge(name="job")

        us = _bench(roundtrip, n_iter=3)
        _row(f"spool_roundtrip_{args.ranks}_ranks", us,
             f"{args.ranks / (us / 1e6):.0f} ranks/s")

        # mid-run snapshot path: overwrite-in-place + partial-rank merge
        def sample_roundtrip():
            for r, res in enumerate(results):
                spool.submit_sample(res, rank=r)
            return spool.merge_samples(name="job")

        us = _bench(sample_roundtrip, n_iter=3)
        _row(f"sample_spool_roundtrip_{args.ranks}_ranks", us,
             f"{args.ranks / (us / 1e6):.0f} ranks/s")
        # on finalized runs the snapshot merge agrees with the post-mortem one
        assert (merge_samples(results, name="job")["region0"].host.as_dict()
                == merge_results(results, name="job")["region0"].host.as_dict())

    if not bench_incremental_sample(args.sample_records,
                                    args.sample_target_speedup):
        print("FAIL: incremental sample speedup below target", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    print("name,us_per_call,derived")
    sys.exit(main())
