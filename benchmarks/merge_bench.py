"""Multi-rank merge micro-benchmark: cost of central aggregation as the
job scales in ranks, plus the file-spool transport round trip.

Prints ``name,us_per_call,derived`` CSV rows (same convention as run.py).

Usage:
  PYTHONPATH=src python benchmarks/merge_bench.py [--ranks 64]
"""

from __future__ import annotations

import argparse
import sys
import tempfile
import time

from repro.core import DeviceActivity, TalpMonitor
from repro.core.merge import FileSpoolTransport, merge_results


def _bench(fn, n_iter: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6  # us


def _row(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def simulate_rank(rank: int, n_regions: int = 8) -> object:
    """One synthetic rank result with several regions + device records."""
    clk = _Clock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    for i in range(n_regions):
        with mon.region(f"region{i}"):
            clk.advance(1.0 + 0.1 * ((rank + i) % 5))
            with mon.offload():
                clk.advance(0.5)
    t = 0.0
    for i in range(64):  # 64 activity records per rank
        mon.add_device_record(0, DeviceActivity.KERNEL, t, t + 0.05)
        mon.add_device_record(0, DeviceActivity.MEMORY, t + 0.05, t + 0.06)
        t += 0.1
    return mon.finalize()


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=64)
    args = ap.parse_args()

    for n in (4, 16, args.ranks):
        results = [simulate_rank(r) for r in range(n)]
        us = _bench(lambda: merge_results(results, name="job"))
        job = merge_results(results, name="job")
        pe = job["region0"].host.parallel_efficiency
        _row(f"merge_{n}_ranks", us, f"{n / (us / 1e6):.0f} ranks/s PE={pe:.3f}")
        for region in job.regions.values():
            if region.host:
                region.host.validate()
            if region.device:
                region.device.validate()

    # spool transport round trip (serialize + atomic publish + reload + merge)
    results = [simulate_rank(r) for r in range(args.ranks)]
    with tempfile.TemporaryDirectory() as d:
        spool = FileSpoolTransport(d, world_size=args.ranks)

        def roundtrip():
            for r, res in enumerate(results):
                spool.submit(res, rank=r)
            return spool.merge(name="job")

        us = _bench(roundtrip, n_iter=3)
        _row(f"spool_roundtrip_{args.ranks}_ranks", us,
             f"{args.ranks / (us / 1e6):.0f} ranks/s")
    return 0


if __name__ == "__main__":
    print("name,us_per_call,derived")
    sys.exit(main())
