"""Multi-rank merge micro-benchmark: cost of central aggregation as the
job scales in ranks, the file-spool transport round trip, the
incremental-sampling speedup (cached flattened timelines vs re-flattening
the whole record history on every ``sample()``), the columnar
record-engine ingestion gate, and the binary spool-payload gate.

Prints ``name,us_per_call,derived`` CSV rows (same convention as run.py);
``--json out.json`` additionally writes the rows as a BENCH_talp.json
trajectory. Exits nonzero if any perf gate misses its target:

  * incremental ``sample()`` ≥ ``--sample-target-speedup``× the full
    re-flatten baseline;
  * columnar ingestion+compaction ≥ ``--ingest-target-speedup``× the
    retained object-per-record reference (bit-identical merged reports);
  * binary spool round trip ≥ ``--spool-target-speedup``× the JSON
    per-record payload;
  * vectorized Chrome trace export ≥ ``--export-target-speedup``× the
    retained per-event reference exporter (identical parsed events,
    output passes the structural validator);
  * per-step capture (StepSeriesRecorder + watchdog at region close)
    ≥ ``--step-target-speedup``× the full re-flatten baseline AND within
    ``--step-target-fraction`` of a nominal 10ms training step, with the
    cost accounted under the report's ``talp_overhead`` annotation.

Usage:
  PYTHONPATH=src python benchmarks/merge_bench.py [--ranks 64] \
      [--sample-records 100000] [--sample-target-speedup 5] \
      [--ingest-records 100000] [--ingest-target-speedup 10] \
      [--spool-target-speedup 5] [--export-records 100000] \
      [--export-target-speedup 5] [--step-records 100000] \
      [--step-target-speedup 2.5] [--step-target-fraction 0.05] \
      [--json out.json]
"""

from __future__ import annotations

import argparse
import json
import sys
import tempfile
import time

import numpy as np

from repro.core import DeviceActivity, TalpMonitor
from repro.core.merge import (
    FileSpoolTransport,
    merge_results,
    merge_samples,
    result_from_spool_bytes,
    result_from_spool_json,
    result_to_spool_bytes,
    result_to_spool_json,
)
from repro.core.report import to_json
from repro.core.states import DeviceRecord, DeviceTimeline, ObjectPathTimeline

ROWS = []  # (name, us_per_call, derived) — mirrored to --json


def _bench(fn, n_iter: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6  # us


def _row(name: str, us: float, derived) -> None:
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": str(derived)})
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


class _Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


def simulate_rank(rank: int, n_regions: int = 8) -> object:
    """One synthetic rank result with several regions + device records."""
    clk = _Clock()
    mon = TalpMonitor(f"rank{rank}", rank=rank, clock=clk)
    for i in range(n_regions):
        with mon.region(f"region{i}"):
            clk.advance(1.0 + 0.1 * ((rank + i) % 5))
            with mon.offload():
                clk.advance(0.5)
    t = 0.0
    for i in range(64):  # 64 activity records per rank
        mon.add_device_record(0, DeviceActivity.KERNEL, t, t + 0.05)
        mon.add_device_record(0, DeviceActivity.MEMORY, t + 0.05, t + 0.06)
        t += 0.1
    return mon.finalize()


def _sampled_monitor(n_records: int, incremental: bool) -> TalpMonitor:
    """Monitor with an open region and a long device-record history —
    the online-sampling scenario (records keep arriving, sample() is
    called periodically)."""
    clk = _Clock()
    mon = TalpMonitor("sampled", clock=clk, incremental=incremental)
    mon.open_region("loop")
    t = 0.0
    for i in range(n_records):
        kind = DeviceActivity.KERNEL if i % 4 else DeviceActivity.MEMORY
        # heavy overlap: flattened arrays stay small, so the cost under
        # measurement is the per-sample record folding, not the metric math
        mon.add_device_record(0, kind, t, t + 0.003)
        t += 0.001
    clk.advance(t + 1.0)
    return mon


def bench_incremental_sample(n_records: int, target_speedup: float) -> bool:
    """sample() on an n_records timeline: incremental (cached flattened
    timelines, fold only new records) vs full re-flatten baseline."""
    base_mon = _sampled_monitor(n_records, incremental=False)
    inc_mon = _sampled_monitor(n_records, incremental=True)

    us_base = _bench(lambda: base_mon.sample("loop"), n_iter=3)
    us_inc = _bench(lambda: inc_mon.sample("loop"), n_iter=3)
    speedup = us_base / us_inc if us_inc > 0 else float("inf")
    _row(f"sample_full_reflatten_{n_records}", us_base, "baseline")
    _row(f"sample_incremental_{n_records}", us_inc,
         f"{speedup:.1f}x vs baseline (target {target_speedup:.1f}x)")

    # consistency: both paths must report identical metrics
    b, i = base_mon.sample("loop"), inc_mon.sample("loop")
    assert b.host.parallel_efficiency == i.host.parallel_efficiency
    assert b.device.parallel_efficiency == i.device.parallel_efficiency

    # informational: cost of a sample right after new records arrive
    # (cache miss -> fold the pending chunk into the compacted arrays)
    def arrival_sample():
        mon = inc_mon
        now = mon.clock()
        for j in range(64):
            mon.add_device_record(0, DeviceActivity.KERNEL,
                                  now + j * 0.001, now + j * 0.001 + 0.003)
        return mon.sample("loop")

    us_arrival = _bench(arrival_sample, n_iter=3)
    _row(f"sample_incremental_arrival_{n_records}", us_arrival,
         "64 new records per sample")
    return speedup >= target_speedup


def _random_columns(n_records: int, seed: int = 0):
    """Random activity columns: ~75% kernels, moderate overlap."""
    rng = np.random.default_rng(seed)
    kinds = np.where(rng.random(n_records) < 0.75, 0, 1).astype(np.uint8)
    starts = np.sort(rng.uniform(0, n_records * 1e-3, n_records))
    ends = starts + rng.uniform(1e-4, 3e-3, n_records)
    streams = rng.integers(0, 4, n_records, dtype=np.uint32)
    return kinds, starts, ends, streams


def bench_ingest_throughput(n_records: int, target_speedup: float) -> bool:
    """Ingestion + compaction: the columnar engine (structured-buffer
    appends, boolean-mask vectorized fold) vs the retained
    object-per-record reference, on identical random streams. The gate
    also requires bit-identical merged job reports from both paths."""
    kinds, starts, ends, streams = _random_columns(n_records)

    def run_object():
        # The object path inherently materializes one DeviceRecord per
        # event from the raw activity buffers — that per-event object
        # traffic is exactly what the columnar engine removes, so it is
        # part of the measured ingestion cost.
        tl = ObjectPathTimeline(device=0)
        tl.ingest(
            DeviceRecord(DeviceActivity.from_code(int(k)), float(s),
                         float(e), int(st))
            for k, s, e, st in zip(kinds, starts, ends, streams)
        )
        tl.compact()
        return tl

    def run_columnar():
        tl = DeviceTimeline(device=0)
        tl.ingest_arrays(kinds, starts, ends, streams)
        tl.compact()
        return tl

    us_obj = _bench(run_object, n_iter=3)
    us_col = _bench(run_columnar, n_iter=3)
    speedup = us_obj / us_col if us_col > 0 else float("inf")
    _row(f"ingest_object_path_{n_records}", us_obj,
         f"{n_records / (us_obj / 1e6) / 1e6:.1f}M rec/s baseline")
    _row(f"ingest_columnar_{n_records}", us_col,
         f"{n_records / (us_col / 1e6) / 1e6:.1f}M rec/s "
         f"{speedup:.1f}x vs object (target {target_speedup:.1f}x)")

    # correctness gate: both record paths must yield bit-identical merged
    # job reports (same host states, same device metric frames)
    def finalize_with(timeline):
        clk = _Clock()
        mon = TalpMonitor("gate", clock=clk)
        mon.devices[0] = timeline
        with mon.region("step"):
            clk.advance(float(ends[-1]))
        return mon.finalize()

    job_obj = merge_results([finalize_with(run_object())], name="job")
    job_col = merge_results([finalize_with(run_columnar())], name="job")
    if to_json(job_obj) != to_json(job_col):
        print("FAIL: columnar and object-path merged reports differ",
              file=sys.stderr)
        return False
    return speedup >= target_speedup


def bench_trace_export(n_records: int, target_speedup: float) -> bool:
    """Chrome trace export of an n_records columnar timeline: the
    vectorized whole-array line generator vs the retained per-event
    reference exporter. The gate also requires the two event streams to
    parse identically and the output to pass the structural validator."""
    from repro.core.states import HostTimeline, Trace
    from repro.core.telemetry.traceexport import (
        export_trace,
        export_trace_reference,
        validate_chrome_trace,
    )

    kinds, starts, ends, streams = _random_columns(n_records, seed=2)
    tl = DeviceTimeline(device=0)
    tl.ingest_arrays(kinds, starts, ends, streams)
    tl.compact()
    elapsed = float(ends[-1])
    trace = Trace(
        name="export-bench",
        hosts={0: HostTimeline(rank=0, useful=elapsed * 0.6,
                               offload=elapsed * 0.3, mpi=elapsed * 0.1)},
        devices={0: tl},
        window=(0.0, elapsed),
    )
    n_slices = sum(
        len(tl.kind_intervals(k))
        for k in (DeviceActivity.KERNEL, DeviceActivity.MEMORY)
    )

    us_ref = _bench(lambda: export_trace_reference(trace), n_iter=3)
    us_vec = _bench(lambda: export_trace(trace), n_iter=3)
    speedup = us_ref / us_vec if us_vec > 0 else float("inf")
    _row(f"trace_export_reference_{n_records}", us_ref,
         f"{n_slices} slices baseline")
    _row(f"trace_export_vectorized_{n_records}", us_vec,
         f"{n_slices / (us_vec / 1e6) / 1e6:.1f}M slices/s "
         f"{speedup:.1f}x vs reference (target {target_speedup:.1f}x)")

    vec, ref = export_trace(trace), export_trace_reference(trace)
    if json.loads(vec)["traceEvents"] != json.loads(ref)["traceEvents"]:
        print("FAIL: vectorized and reference trace exports differ",
              file=sys.stderr)
        return False
    validate_chrome_trace(vec)
    return speedup >= target_speedup


def bench_spool_payload(n_records: int, target_speedup: float) -> bool:
    """Spool round trip (serialize + parse) with raw device timelines
    attached: versioned binary NPZ payload vs per-record JSON."""
    kinds, starts, ends, streams = _random_columns(n_records, seed=1)
    clk = _Clock()
    mon = TalpMonitor("spool", clock=clk)
    mon.ingest_device_arrays(0, kinds, starts, ends, streams)
    with mon.region("step"):
        clk.advance(float(ends[-1]))
    result = mon.finalize()
    timelines = mon.devices

    def roundtrip_json():
        return result_from_spool_json(result_to_spool_json(result, timelines))

    def roundtrip_binary():
        return result_from_spool_bytes(result_to_spool_bytes(result, timelines))

    us_json = _bench(roundtrip_json, n_iter=3)
    us_bin = _bench(roundtrip_binary, n_iter=3)
    speedup = us_json / us_bin if us_bin > 0 else float("inf")
    nbytes = len(result_to_spool_bytes(result, timelines))
    njson = len(result_to_spool_json(result, timelines))
    _row(f"spool_json_payload_{n_records}", us_json, f"{njson} bytes")
    _row(f"spool_binary_payload_{n_records}", us_bin,
         f"{nbytes} bytes {speedup:.1f}x vs json "
         f"(target {target_speedup:.1f}x)")

    # round-trip fidelity: identical report and identical raw intervals
    res_b, tls_b = roundtrip_binary()
    assert to_json(res_b) == to_json(result)
    for kind in (DeviceActivity.KERNEL, DeviceActivity.MEMORY):
        np.testing.assert_array_equal(tls_b[0].kind_intervals(kind),
                                      timelines[0].kind_intervals(kind))
    return speedup >= target_speedup


def _step_series_run(n_records: int, n_steps: int, incremental: bool):
    """Drive n_steps ``step`` regions with ~n_records total device
    records through a StepSeriesRecorder + watchdog — the per-step
    attribution hot path. Returns (monitor, recorder, wall seconds)."""
    from repro.core.telemetry.stepseries import StepSeriesRecorder
    from repro.core.telemetry.watchdog import EfficiencyWatchdog

    per = max(1, n_records // n_steps)
    clk = _Clock()
    mon = TalpMonitor("steps", clock=clk, incremental=incremental,
                      overhead_report=True)
    rec = StepSeriesRecorder(mon, capacity=n_steps,
                             watchdog=EfficiencyWatchdog())
    kinds = np.zeros(per, dtype=np.uint8)
    streams = np.zeros(per, dtype=np.uint32)
    offsets = np.arange(per, dtype=np.float64) * 1e-5
    t_wall0 = time.perf_counter()
    for _ in range(n_steps):
        with mon.region("step"):
            starts = clk.t + offsets
            mon.ingest_device_arrays(0, kinds, starts, starts + 8e-6, streams)
            with mon.offload():
                clk.advance(per * 1e-5)
            clk.advance(1e-5)
    wall = time.perf_counter() - t_wall0
    rec.close()
    return mon, rec, wall


def bench_step_series(n_records: int, target_speedup: float,
                      target_fraction: float,
                      nominal_step_ms: float = 10.0) -> bool:
    """Per-step capture cost at an n_records device-record history:
    incremental flattened-timeline cache (fold only the step's new
    records at each region close) vs the full re-flatten baseline.

    Two gates: the incremental path must beat the baseline by
    ``target_speedup``×, and its per-step capture cost must stay within
    ``target_fraction`` of a ``nominal_step_ms`` training step — the
    bounded-overhead claim for leaving the watchdog on in production.
    The cost is also required to be visible in the report's
    ``talp_overhead`` annotation (``step`` section)."""
    n_steps = 200
    mon_base, _, wall_base = _step_series_run(n_records, n_steps,
                                              incremental=False)
    mon_inc, rec, wall_inc = _step_series_run(n_records, n_steps,
                                              incremental=True)

    us_base = mon_base.overhead.totals["step"] / n_steps * 1e6
    us_inc = mon_inc.overhead.totals["step"] / n_steps * 1e6
    speedup = us_base / us_inc if us_inc > 0 else float("inf")
    fraction = (us_inc / 1e6) / (nominal_step_ms / 1e3)
    _row(f"step_series_full_reflatten_{n_records}", us_base,
         "per-step capture, baseline")
    _row(f"step_series_incremental_{n_records}", us_inc,
         f"{speedup:.1f}x vs baseline (target {target_speedup:.1f}x)")
    _row(f"step_series_overhead_{n_records}", us_inc,
         f"{fraction * 100:.2f}% of a {nominal_step_ms:.0f}ms step "
         f"(target {target_fraction * 100:.1f}%)")

    # every step captured, device metrics present in the rows
    assert len(rec.series) == n_steps and rec.series.n_dropped == 0
    lb = rec.series.column("device_load_balance")
    assert np.isfinite(lb).all()
    # the cost is accounted in the report's talp_overhead annotation
    res = mon_inc.finalize()
    ov = res.regions[TalpMonitor.GLOBAL].host.talp_overhead
    assert ov is not None and mon_inc.overhead.counts["step"] == n_steps
    del wall_base, wall_inc

    ok = True
    if speedup < target_speedup:
        print("FAIL: per-step capture speedup below target", file=sys.stderr)
        ok = False
    if fraction > target_fraction:
        print("FAIL: per-step capture overhead fraction above target",
              file=sys.stderr)
        ok = False
    return ok


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ranks", type=int, default=64)
    ap.add_argument("--sample-records", type=int, default=100_000)
    # The incremental-sample gate is relative to the full re-flatten
    # baseline; the single-key flatten() sort fast-path sped that
    # baseline up ~7x, so the ratio compressed from >5x to ~3x while the
    # absolute incremental sample cost also improved.
    ap.add_argument("--sample-target-speedup", type=float, default=2.5)
    ap.add_argument("--ingest-records", type=int, default=100_000)
    ap.add_argument("--ingest-target-speedup", type=float, default=10.0)
    ap.add_argument("--spool-records", type=int, default=100_000)
    ap.add_argument("--spool-target-speedup", type=float, default=5.0)
    ap.add_argument("--export-records", type=int, default=100_000)
    ap.add_argument("--export-target-speedup", type=float, default=5.0)
    ap.add_argument("--step-records", type=int, default=100_000)
    ap.add_argument("--step-target-speedup", type=float, default=2.5)
    ap.add_argument("--step-target-fraction", type=float, default=0.05,
                    help="per-step capture budget as a fraction of a "
                         "nominal 10ms training step")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the rows as a BENCH_talp.json trajectory")
    args = ap.parse_args()

    for n in (4, 16, args.ranks):
        results = [simulate_rank(r) for r in range(n)]
        us = _bench(lambda: merge_results(results, name="job"))
        job = merge_results(results, name="job")
        pe = job["region0"].host.parallel_efficiency
        _row(f"merge_{n}_ranks", us, f"{n / (us / 1e6):.0f} ranks/s PE={pe:.3f}")
        for region in job.regions.values():
            if region.host:
                region.host.validate()
            if region.device:
                region.device.validate()

    # spool transport round trip (serialize + atomic publish + reload + merge)
    results = [simulate_rank(r) for r in range(args.ranks)]
    with tempfile.TemporaryDirectory() as d:
        spool = FileSpoolTransport(d, world_size=args.ranks)

        def roundtrip():
            for r, res in enumerate(results):
                spool.submit(res, rank=r)
            return spool.merge(name="job")

        us = _bench(roundtrip, n_iter=3)
        _row(f"spool_roundtrip_{args.ranks}_ranks", us,
             f"{args.ranks / (us / 1e6):.0f} ranks/s")

        # mid-run snapshot path: overwrite-in-place + partial-rank merge
        def sample_roundtrip():
            for r, res in enumerate(results):
                spool.submit_sample(res, rank=r)
            return spool.merge_samples(name="job")

        us = _bench(sample_roundtrip, n_iter=3)
        _row(f"sample_spool_roundtrip_{args.ranks}_ranks", us,
             f"{args.ranks / (us / 1e6):.0f} ranks/s")
        # on finalized runs the snapshot merge agrees with the post-mortem one
        assert (merge_samples(results, name="job")["region0"].host.as_dict()
                == merge_results(results, name="job")["region0"].host.as_dict())

    rc = 0
    if not bench_incremental_sample(args.sample_records,
                                    args.sample_target_speedup):
        print("FAIL: incremental sample speedup below target", file=sys.stderr)
        rc = 1
    if not bench_ingest_throughput(args.ingest_records,
                                   args.ingest_target_speedup):
        print("FAIL: columnar ingestion speedup below target", file=sys.stderr)
        rc = 1
    if not bench_spool_payload(args.spool_records,
                               args.spool_target_speedup):
        print("FAIL: binary spool speedup below target", file=sys.stderr)
        rc = 1
    if not bench_trace_export(args.export_records,
                              args.export_target_speedup):
        print("FAIL: trace export speedup below target", file=sys.stderr)
        rc = 1
    if not bench_step_series(args.step_records,
                             args.step_target_speedup,
                             args.step_target_fraction):
        rc = 1
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "talp", "rows": ROWS}, f, indent=1)
    return rc


if __name__ == "__main__":
    print("name,us_per_call,derived")
    sys.exit(main())
