"""Benchmark harness — one function per paper table/figure, plus
framework benches. Prints ``name,us_per_call,derived`` CSV rows.

  * fig4..fig10   — PILS use cases 1–7 (§5.1): derived = the use case's
                    headline metric, asserted against the paper's value.
  * table1..3     — SOD2D / FALL3D / XSHELLS node scans (§5.2): derived =
                    key metric at 8 nodes; the full node-scan table is
                    printed to stderr for inspection.
  * talp_overhead — the "lightweight monitoring" claim: cost of a
                    region enter/exit + state scope per step.
  * flatten_throughput — interval post-processing throughput (records/s).
  * kernel_*      — Pallas kernels (interpret mode) vs jnp oracle.
  * roofline_cells — summary over the dry-run JSONs (if present).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

ROWS = []  # (name, us_per_call, derived) — mirrored to --json


def _bench(fn, n_iter: int = 5, warmup: int = 1):
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n_iter):
        fn()
    return (time.perf_counter() - t0) / n_iter * 1e6  # us


def _row(name: str, us: float, derived):
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": str(derived)})
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


# ---------------------------------------------------------------------------
# Figs 4–10: PILS use cases
# ---------------------------------------------------------------------------
def bench_pils():
    from repro.pils import run_use_case

    heads = {
        "uc1": ("fig4_uc1", lambda a: a["trace"].device.orchestration_efficiency),
        "uc2": ("fig5_uc2", lambda a: a["trace"].host.device_offload_efficiency),
        "uc3": ("fig6_uc3", lambda a: a["trace"].device.load_balance),
        "uc4": ("fig7_uc4", lambda a: a["trace"].host.load_balance),
        "uc5": ("fig8_uc5", lambda a: a["trace"].device.orchestration_efficiency),
        "uc6": ("fig9_uc6", lambda a: a["trace"].device.communication_efficiency),
        "uc7": ("fig10_uc7", lambda a: a["overlap"].host.device_offload_efficiency
                - a["no_overlap"].host.device_offload_efficiency),
    }
    for uc, (name, metric) in heads.items():
        res = {}

        def run(uc=uc, res=res):
            res["r"] = run_use_case(uc)

        us = _bench(run)
        val = metric(res["r"].analyses)
        _row(name, us, f"{val:.3f}")


# ---------------------------------------------------------------------------
# Tables 1–3: application node scans
# ---------------------------------------------------------------------------
def bench_app_tables():
    from repro.appsim import node_scan
    from repro.core.report import node_scan_table

    for i, app in enumerate(("sod2d", "fall3d", "xshells"), start=1):
        res = {}

        def run(app=app, res=res):
            res["scan"] = node_scan(app)

        us = _bench(run, n_iter=3)
        scan = res["scan"]
        table = node_scan_table(
            [scan[n] for n in (1, 2, 4, 8)], ["1", "2", "4", "8"],
            title=f"TALP Output for {app.upper()} from 1 to 8 nodes",
        )
        print(table, file=sys.stderr)
        derived = scan[8].device.orchestration_efficiency
        _row(f"table{i}_{app}", us, f"orch@8={derived:.3f}")


# ---------------------------------------------------------------------------
# TALP overhead (the "lightweight" claim)
# ---------------------------------------------------------------------------
def bench_talp_overhead():
    from repro.core.talp import TalpMonitor

    mon = TalpMonitor("bench")
    n = 10000

    def run():
        for _ in range(n):
            mon.open_region("step")
            with mon.offload():
                pass
            mon.close_region("step")

    us = _bench(run, n_iter=3) / n
    _row("talp_region_overhead", us, f"{us:.3f}us/step")

    def run_sample():
        mon.sample("step")

    us2 = _bench(run_sample, n_iter=20)
    _row("talp_online_sample", us2, "per-call")

    # record-ingestion throughput: scalar add() vs columnar ingest_arrays()
    from repro.core.states import DeviceActivity, DeviceTimeline

    m = 50_000
    rng = np.random.default_rng(0)
    starts = np.sort(rng.uniform(0, m * 1e-3, m))
    ends = starts + rng.uniform(1e-4, 3e-3, m)

    def run_scalar():
        tl = DeviceTimeline(device=0)
        for s, e in zip(starts, ends):
            tl.add(DeviceActivity.KERNEL, s, e)
        tl.compact()

    def run_columnar():
        tl = DeviceTimeline(device=0)
        tl.ingest_arrays(DeviceActivity.KERNEL, starts, ends)
        tl.compact()

    us3 = _bench(run_scalar, n_iter=3)
    _row("talp_ingest_scalar_add_50k", us3,
         f"{m / (us3 / 1e6) / 1e6:.1f}M rec/s")
    us4 = _bench(run_columnar, n_iter=3)
    _row("talp_ingest_columnar_50k", us4,
         f"{m / (us4 / 1e6) / 1e6:.1f}M rec/s")


def bench_flatten_throughput():
    from repro.core import intervals as iv

    rng = np.random.default_rng(0)
    n = 200_000
    starts = rng.uniform(0, 1000, n)
    recs = np.stack([starts, starts + rng.uniform(0, 0.02, n)], axis=1)

    def run():
        iv.flatten(recs)

    us = _bench(run, n_iter=5)
    _row("flatten_200k_records", us, f"{n / (us / 1e6) / 1e6:.1f}M rec/s")

    kern = iv.flatten(recs[: n // 2])
    mem = recs[n // 2:]

    def run_sub():
        iv.subtract(mem, kern)

    us2 = _bench(run_sub, n_iter=3)
    _row("subtract_100k_records", us2, "memory-overlap removal")


# ---------------------------------------------------------------------------
# kernels (interpret mode — correctness-path cost, not TPU perf)
# ---------------------------------------------------------------------------
def bench_kernels():
    import jax
    import jax.numpy as jnp
    from repro.kernels.flash_attention.kernel import flash_attention
    from repro.kernels.flash_attention.ref import attention_reference
    from repro.kernels.ssd.kernel import ssd_pallas
    from repro.kernels.ssd.ref import ssd_reference

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (1, 256, 4, 64))
    k = jax.random.normal(ks[1], (1, 256, 2, 64))
    v = jax.random.normal(ks[2], (1, 256, 2, 64))

    out_k = flash_attention(q, k, v, interpret=True)
    out_r = attention_reference(q, k, v)
    err = float(jnp.abs(out_k - out_r).max())
    us = _bench(lambda: jax.block_until_ready(
        flash_attention(q, k, v, interpret=True)), n_iter=3)
    _row("kernel_flash_attn_interpret", us, f"maxerr={err:.2e}")
    us_ref = _bench(lambda: jax.block_until_ready(
        jax.jit(attention_reference)(q, k, v)), n_iter=3)
    _row("kernel_flash_attn_ref_xla", us_ref, "oracle")

    x = jax.random.normal(ks[0], (1, 256, 4, 32))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, 256, 4)))
    a = -jnp.exp(jax.random.normal(ks[2], (4,)) * 0.3)
    bm = jax.random.normal(ks[3], (1, 256, 1, 32))
    cm = jax.random.normal(ks[4], (1, 256, 1, 32))
    out_k = ssd_pallas(x, dt, a, bm, cm, chunk=64, interpret=True)
    out_r = ssd_reference(x, dt, a, bm, cm, chunk=64)
    err = float(jnp.abs(out_k - out_r).max())
    us = _bench(lambda: jax.block_until_ready(
        ssd_pallas(x, dt, a, bm, cm, chunk=64, interpret=True)), n_iter=3)
    _row("kernel_ssd_interpret", us, f"maxerr={err:.2e}")


# ---------------------------------------------------------------------------
# roofline summary over dry-run artifacts
# ---------------------------------------------------------------------------
def bench_roofline_cells():
    base = os.path.join(os.path.dirname(__file__), "..", "experiments")
    d = os.path.join(base, "dryrun_single_opt")      # optimized sweep
    if not os.path.isdir(d):
        d = os.path.join(base, "dryrun_single")      # baseline fallback
    if not os.path.isdir(d):
        _row("roofline_cells", 0.0, "no dry-run artifacts (run dryrun --all)")
        return
    fracs = []
    t0 = time.perf_counter()
    for name in sorted(os.listdir(d)):
        with open(os.path.join(d, name)) as f:
            cell = json.load(f)
        if cell.get("status") != "ok":
            continue
        fracs.append((cell["roofline_fraction"], cell["arch"], cell["shape"],
                      cell["dominant"]))
    us = (time.perf_counter() - t0) * 1e6
    if not fracs:
        _row("roofline_cells", us, "none")
        return
    fracs.sort()
    worst = fracs[0]
    best = fracs[-1]
    med = fracs[len(fracs) // 2]
    _row("roofline_cells", us,
         f"n={len(fracs)} worst={worst[0]:.3f}({worst[1]}/{worst[2]}) "
         f"median={med[0]:.3f} best={best[0]:.3f}({best[1]}/{best[2]})")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the rows as a BENCH_talp.json trajectory")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    bench_pils()
    bench_app_tables()
    bench_talp_overhead()
    bench_flatten_throughput()
    bench_kernels()
    bench_roofline_cells()
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"bench": "talp", "rows": ROWS}, f, indent=1)


if __name__ == "__main__":
    main()
